"""Assemble EXPERIMENTS.md: generated §Dry-run/§Roofline + static §Perf /
§Paper-validation narrative (the measured hillclimb log)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.report import dryrun_section, roofline_section

ROOT = os.path.join(os.path.dirname(__file__), "..")

HEADER = """# EXPERIMENTS

Regenerate the generated sections with
`PYTHONPATH=src:. python -m benchmarks.assemble_experiments` after
`python -m repro.launch.dryrun --all --both-meshes`.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per
link.  All per-chip quantities come from the trip-count-aware SPMD-HLO
analyzer (`repro.launch.hlo_analysis`) — see the caveats note at the end.
"""


def perf_table(target, legs):
    rows = ["| variant | flags | compute | memory | collective | peak HBM |",
            "|---|---|---|---|---|---|"]
    for tag, flags in legs:
        path = os.path.join(ROOT, "experiments/perf",
                            f"{target}__{tag}.json")
        if not os.path.exists(path):
            rows.append(f"| {tag} | {flags} | (missing) | | | |")
            continue
        r = json.load(open(path))
        h, c = r["hlo"], r["collectives"]
        peak = r["memory"].get("peak_memory_in_bytes", 0) / 1e9
        rows.append(
            f"| {tag} | `{flags}` | {h['flops']/197e12:.3g}s "
            f"| {h['bytes']/819e9:.3g}s | {c['total_bytes']/50e9:.3g}s "
            f"| {peak:.2f}GB{' **>16GB**' if peak > 16 else ''} |")
    return "\n".join(rows)


PERF_INTRO = """## §Perf — hillclimb log (three pairs)

Pairs chosen per the brief's rule from the baseline roofline table:

* **Target B — deepseek-v3-671b x train_4k**: worst roofline state (peak
  HBM/chip exceeds the 16GB of a v5e: the combo does not fit).
* **Target A — internvl2-1b x prefill_32k**: most collective-bound
  (collective term > memory > 300x compute at baseline).
* **Target C — qwen3-4b x decode_32k**: most representative of the paper's
  technique (KV-cache autoregressive decode — pillar P1's home turf).

Method: hypothesis -> napkin math -> change (env-gated perf flag) ->
re-lower -> re-analyze -> confirm/refute.  Baselines are paper-faithful
(`REPRO_PERF_OPTS=""`); artifacts in `experiments/perf/`.
"""

TARGET_B = """### Target B: deepseek-v3-671b / train_4k (fit the pod)

Baseline state: full-AdamW training of 671B params on 256 x 16GB chips.
Napkin: fp32 master params (4B) + two bf16 moments (2+2B) = 8B/param
-> 671e9 x 8 / 256 = **21.0GB/chip** before activations — cannot fit, and
the dry-run confirms (peak 21.7GB).

| hypothesis | napkin | measured |
|---|---|---|
| H-B1: bf16 param storage (DeepSeek itself trained in fp8; bf16 is the conservative TPU analogue) saves 671e9x2/256 = 5.2GB | 21.7 -> 16.5GB | 21.72 -> **16.29GB** — confirmed (still over) |
| H-B2: Adafactor-style factored second moment + momentum-free saves both bf16 moments (2x5.2GB) minus tiny row/col stats | 16.3 -> ~5.9GB | 16.29 -> **5.47GB** — confirmed, **fits with 2.9x headroom** |
| H-B3: grad_accum=4 microbatching shrinks activation/logit peaks further | -1-2GB | 5.47 -> 5.47GB, +1% FLOPs, +2% collectives — **refuted** (remat already bounds activations; the binding term was optimizer state) |

"""

TARGET_A = """### Target A: internvl2-1b / prefill_32k (collective wall)

Baseline diagnosis: 1.50TB/chip of collectives (841 all-reduces = ~35 per
layer — not the 2/layer of healthy Megatron TP).  Root cause: 14 query /
2 KV heads do not divide the 16-way `model` axis, so GSPMD reshards full
activations around every per-head reshape.

| hypothesis | napkin | measured |
|---|---|---|
| H-A1: attn_bf16 halves fp32 attention traffic | mem -5-10% | bytes 20.8 -> 19.4TB (-7%), collectives unchanged — confirmed, minor |
| H-A2: tp_attn_guard (replicate attention weights, attention runs data-parallel) removes per-head reshards | coll 30s -> <1s | coll **30.0s -> 0.63s (-48x)** — confirmed; BUT compute 0.073 -> 0.98s and memory 25.4 -> 40.7s (replication over the idle model axis) — **net negative on the max-term estimate** |
| H-A3: + seq_parallel (shard the 32k sequence over `model` so the replicated compute divides back down) | compute ~1/16 | compute 1.18s, coll 1.06s — **refuted**: the chunked-attention block reshape breaks sequence sharding, GSPMD re-gathers |

Outcome: the collective wall is removable (H-A2) but the fixed 16x16 mesh
is simply oversized for a 0.9B model at TP=16.  The production answer is
mesh reconfiguration (DP-heavy submeshes) or a sequence-sharding-preserving
attention (ring attention) — recorded as the next iteration beyond this
budget.  Three consecutive <5%-or-negative changes -> stop per protocol.

"""

TARGET_C = """### Target C: qwen3-4b / decode_32k (the paper's own regime)

Baseline: memory-dominant (as expected for batch decode: read 620GB of KV
cache + 8GB of weights per global step; per chip 3.9GB cache reads).

| hypothesis | napkin | measured |
|---|---|---|
| H-C1: attn_bf16 — FasterTransformer computes attention in half precision; the fp32-cast jnp reference materializes an fp32 copy of every cache tile | mem -10-50% | bytes 48.7 -> **43.8GB (-10%)** — confirmed (the residual gap is CPU-HLO double-buffered scan carries; a TPU compile aliases them) |
| H-C2 (engine, wall-clock): fuse the greedy decode loop into one lax.scan — removes per-token dispatch + host sync | step overhead -> 0 | Table-1 stage 2 went 1.02x -> **1.21x** over baseline on the CPU host (see §Paper-validation) — confirmed |
| H-C4 (engine, wall-clock): prefix caching — radix trie shares prompt-prefix KV *pages* across requests, copy-on-write (`core/prefix_cache.py`, `engine.set_prefix` seeds/pins) | prefill cost ~ suffix/total | **1.84x** measured continuous-serve tokens/s at 64 requests over 8 distinct 224-token prompts (~80% prefill tokens saved, hit-rate 0.80), outputs bit-identical (`benchmarks/serving_bench.py --trace shared`, `examples/prefix_serving.py`) — confirmed |
| H-C3: analyzer fidelity — in-place scatter/DUS cache writes under donation must be charged the written slice, not the 2.4GB buffer | bytes -5-10x | per-chip bytes 434 -> 48.7GB baseline restatement (analyzer v3; both recorded) — confirmed |

Essential-traffic floor (napkin): 3.9GB cache + 0.5GB weight shard
= 4.4GB/chip/step = 5.4ms vs measured-model 53ms — the remaining 10x is
unfused-CPU-HLO artifact, bounded and documented below.

**Promoted defaults** after this pass: `attn_bf16` (paper-faithful:
FT uses fp16 compute).  `tp_attn_guard`, `seq_parallel`, `bf16_params`,
`factored_opt`, `grad_accum` stay opt-in per arch/scale.

### Bonus: MoE dispatch backend (qwen3-moe-235b / decode_32k)

Hypothesis: `jax.lax.ragged_dot` grouped matmul (no capacity, no token
drops, no padded (E,C,d) buffer) beats the GShard capacity einsum.
Measured (`experiments/perf/...__ragged.json`): it *lowers* on the
256-chip mesh but GSPMD cannot shard the ragged group dimension over the
expert axis — per-chip FLOPs 0.09T -> 1.88T (replicated expert compute),
bytes +33%, collectives +140%.  **Refuted for the distributed setting**:
ragged dispatch stays the single-host/quality option (exactness tested
vs the capacity path), the expert-sharded capacity einsum remains the
production default.

"""

VALIDATION = """## §Paper-validation (Table-1 reproduction)

`python -m benchmarks.table1` (also `examples/serve_batched.py`) runs the
paper's four cumulative stages on a scaled UNIMO-text over a synthetic
Zipf workload (the paper's dataset is proprietary).  Paper numbers are
GPU samples/s; ours are CPU-host samples/s — the deliverable is the
cumulative structure:

| stage | paper (GPU, full scale) | this repo (CPU host) |
|---|---|---|
| baseline | 16.11 (1.0x) | 3.41 (1.0x) |
| + fast transformer (KV+half+fused) | 98.46 (6.1x) | 4.13 (1.21x) |
| + embedding pruning | 125.32 (7.8x) | 17.03 (4.99x) |
| + multi-process pipeline | 144.45 (8.96x) | 16.95 (4.97x) |

Host-effect analysis (DESIGN.md §3): (a) the KV-cache stage's 6.1x on GPU
collapses to 1.22x on one CPU core because skinny decode GEMMs lose their
parallel-hardware advantage and bf16 is emulated — the decode_32k roofline
(Target C) shows the TPU-side win the host cannot; (b) the pipeline stage
overlaps CPU pre/post-processing with *accelerator* compute; with the model
on the same single core there is nothing to overlap with (mechanism
verified by equivalence tests instead).  The pruning stage's win (4.2x
measured with fp32, 4.99x cumulative with bf16) is host-independent:
smaller embedding gather + 512->128 padding, exactly the paper's Figure-3
argument.  Quality preservation is validated structurally: pruning keeps
kept-token logits bit-identical (test), half-precision logits stay within
tolerance with >70% greedy-argmax agreement (test).
"""

CAVEATS = """## Analyzer caveats (applies to all byte numbers)

1. `compiled.cost_analysis()` visits while bodies once; our analyzer
   multiplies by trip counts (validated against hand-computed scans in
   `tests/test_hlo_analysis.py`).
2. Bytes are operand+output per instruction with fusion-parameter usage
   analysis (sliced reads charged the slice; donated scatter/DUS writes
   charged the written window; fp32<->bf16 convert chains treated as
   register traffic).  This is an *upper bound*: XLA-CPU fuses less than
   XLA-TPU, and scan double-buffering that TPU aliases in place is still
   counted.  Essential-traffic floors are given in §Perf where relevant.
3. Collective bytes are output-shape bytes of collective ops (the standard
   proxy; exact for all-gather, ~1x ring payload for all-reduce).
4. deepseek/qwen3-moe train shapes use bf16 optimizer moments in the
   *baseline* dry-run (`LOW_MEM_OPT_THRESHOLD`) — full-fp32 AdamW for 671B
   params cannot be expressed on 256 chips at all; §Perf Target B treats
   the remaining gap.
"""


def main():
    parts = [
        HEADER,
        VALIDATION,
        PERF_INTRO,
        TARGET_B + perf_table(
            "deepseek-v3-671b__train_4k__16x16",
            [("base", ""), ("attnbf16", "attn_bf16"),
             ("bf16p", "attn_bf16,bf16_params"),
             ("bf16p_fact", "attn_bf16,bf16_params,factored_opt"),
             ("bf16p_fact_ga4",
              "attn_bf16,bf16_params,factored_opt,grad_accum=4")]),
        TARGET_A + perf_table(
            "internvl2-1b__prefill_32k__16x16",
            [("base", ""), ("attnbf16", "attn_bf16"),
             ("tpguard", "attn_bf16,tp_attn_guard"),
             ("tpguard_seqpar", "attn_bf16,tp_attn_guard,seq_parallel")]),
        TARGET_C + perf_table(
            "qwen3-4b__decode_32k__16x16",
            [("base", ""), ("attnbf16", "attn_bf16")]),
        dryrun_section(),
        roofline_section(),
        CAVEATS,
    ]
    out = "\n\n".join(parts) + "\n"
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(out)
    print(f"EXPERIMENTS.md written ({len(out)} chars)")


if __name__ == "__main__":
    main()
