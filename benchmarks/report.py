"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts.  (§Perf and §Paper-validation are written by hand from
the hillclimb log and Table-1 runs.)
"""
from __future__ import annotations

import json
import os
from collections import defaultdict

from benchmarks.roofline import (DRYRUN_DIR, fmt_seconds, load_records,
                                 table, terms)


def _gb(x):
    return f"{x/1e9:.2f}GB"


def dryrun_section() -> str:
    recs = load_records(mesh=None)
    by_mesh = defaultdict(list)
    for r in recs:
        by_mesh[r["mesh"]].append(r)
    lines = ["## §Dry-run", "",
             f"{len(recs)} (arch x shape x mesh) combinations lowered and "
             "compiled (`python -m repro.launch.dryrun --all "
             "--both-meshes`); artifacts in `experiments/dryrun/`.", ""]
    for mesh in ("16x16", "2x16x16"):
        rs = by_mesh.get(mesh, [])
        lines.append(f"### mesh {mesh} ({rs[0]['chips'] if rs else '?'} "
                     f"chips) — {len(rs)} combos")
        lines.append("")
        lines.append("| arch | shape | compile | peak bytes/chip | "
                     "HLO GFLOPs/chip | collective MB/chip | "
                     "top collective |")
        lines.append("|---|---|---|---|---|---|---|")
        for r in sorted(rs, key=lambda r: (r["arch"], r["shape"])):
            mem = r.get("memory", {})
            peak = mem.get("peak_memory_in_bytes", 0)
            coll = r.get("collectives", {})
            per = coll.get("per_op_bytes", {})
            top = max(per, key=per.get) if per and any(per.values()) else "-"
            hlo = r.get("hlo", {})
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['compile_s']}s "
                f"| {_gb(peak)} | {hlo.get('flops', 0)/1e9:.1f} "
                f"| {coll.get('total_bytes', 0)/1e6:.1f} | {top} |")
        lines.append("")
    return "\n".join(lines)


def roofline_section() -> str:
    rows = table(load_records(mesh="16x16"))
    lines = [
        "## §Roofline (single-pod 16x16, per chip)", "",
        "Terms per the brief: compute = HLO_FLOPs/(chips x 197 TFLOP/s), "
        "memory = HLO_bytes/(chips x 819 GB/s), collective = "
        "collective_bytes/(chips x 50 GB/s).  HLO quantities come from the "
        "trip-count-aware analyzer (`repro.launch.hlo_analysis`) over the "
        "per-chip SPMD program, so per-chip values divide out directly. "
        "In-place ops (scatter/gather/DUS) are charged only their moved "
        "slices (buffer donation, paper P3).", "",
        "| arch | shape | compute | memory | collective | dominant | "
        "useful% | fits 16GB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for t in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {t['arch']} | {t['shape']} | {fmt_seconds(t['compute_s'])} "
            f"| {fmt_seconds(t['memory_s'])} "
            f"| {fmt_seconds(t['collective_s'])} | **{t['dominant']}** "
            f"| {100*t['useful_frac']:.1f}% "
            f"| {'yes' if t['fits_hbm'] else '**NO**'} |")
    lines.append("")
    # bottleneck summary
    doms = defaultdict(int)
    for t in rows:
        doms[t["dominant"]] += 1
    lines.append("Dominant-term census: "
                 + ", ".join(f"{k}: {v}" for k, v in sorted(doms.items())))
    lines.append("")
    return "\n".join(lines)


def main():
    print(dryrun_section())
    print()
    print(roofline_section())


if __name__ == "__main__":
    main()
