"""Bench regression gate: diff a fresh serving_bench report against a
committed baseline JSON (BENCH_serving / BENCH_longprompt / BENCH_overload).

Two families of checks:

* **Invariants** run against the fresh report alone — correctness bits
  (``outputs_identical_*``), structural guarantees (packed serving does
  exactly one dispatch per mixed iteration), and bounded-waste ratios.
  These must hold for *any* run shape, so they gate CI smokes whose
  config differs from the committed baseline.
* **Baseline-relative** checks compare fresh vs baseline numbers with a
  per-metric tolerance.  Ratios of wall-clock measurements on shared CI
  runners are noisy, so tolerances are deliberately loose (they catch
  "packed serving got 2x slower", not 5% drift) — and they only run at
  all when the run *config* matches the baseline's (same arch, request
  count, slot count, max_new, trace shape).  A config mismatch is not a
  failure: invariants still gate, relative checks are skipped and noted.

Exit status 0 = all checks pass, 1 = at least one FAIL.  ``--verdict-out``
writes a machine-readable verdict JSON with every check's outcome.
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

# Run-shape keys that must match for baseline-relative comparisons to be
# meaningful.  serving_bench stamps all of these at the top level.
CONFIG_KEYS = ("arch", "requests", "slots", "max_new", "trace")

_MISSING = object()


def get_path(d: Dict[str, Any], path: str) -> Any:
    """Walk a dot-separated path; returns _MISSING if any hop is absent."""
    cur: Any = d
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return _MISSING
        cur = cur[part]
    return cur


@dataclass
class Check:
    """One gate: an invariant on the fresh report, or a fresh-vs-baseline
    comparison.

    mode:
      'true'      fresh value must be exactly True
      'eq'        fresh value == ``value`` (within abs_tol for floats)
      'ge'/'le'   fresh value >=/<= ``value``
      'rel'       baseline-relative: fresh may degrade from baseline by at
                  most ``base*rel_tol + abs_tol`` in the bad direction
                  (``higher_better`` selects which direction is bad)
    if_present: skip (not fail) when the path is absent from the fresh
      report AND absent from the baseline; if the baseline has the section
      but the fresh report lost it, that's a FAIL (a feature silently
      dropped out of the bench).
    """
    path: str
    mode: str
    value: Any = None
    rel_tol: float = 0.0
    abs_tol: float = 0.0
    higher_better: bool = True
    if_present: bool = False


def _fmt(v: Any) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return repr(v)


def run_check(c: Check, fresh: Dict[str, Any], baseline: Dict[str, Any],
              config_match: bool) -> Dict[str, Any]:
    fv = get_path(fresh, c.path)
    bv = get_path(baseline, c.path)
    out: Dict[str, Any] = {
        "path": c.path, "mode": c.mode,
        "baseline": None if bv is _MISSING else bv,
        "fresh": None if fv is _MISSING else fv,
    }

    if c.mode == "rel":
        if not config_match:
            out.update(status="SKIP", note="config mismatch vs baseline; "
                       "relative comparison not meaningful")
            return out
        if bv is _MISSING:
            out.update(status="SKIP", note="metric absent from baseline")
            return out
        if fv is _MISSING:
            out.update(status="FAIL", note="metric present in baseline but "
                       "missing from fresh report")
            return out
        base = float(bv)
        val = float(fv)
        slack = abs(base) * c.rel_tol + c.abs_tol
        if c.higher_better:
            ok = val >= base - slack
            note = (f"fresh {_fmt(val)} vs baseline {_fmt(base)} "
                    f"(min allowed {_fmt(base - slack)})")
        else:
            ok = val <= base + slack
            note = (f"fresh {_fmt(val)} vs baseline {_fmt(base)} "
                    f"(max allowed {_fmt(base + slack)})")
        out.update(status="PASS" if ok else "FAIL", note=note)
        return out

    # invariant modes evaluate the fresh report alone
    if fv is _MISSING:
        if c.if_present and bv is _MISSING:
            out.update(status="SKIP", note="optional section not in this run")
        elif c.if_present:
            out.update(status="FAIL", note="section present in baseline but "
                       "missing from fresh report")
        else:
            out.update(status="FAIL", note="required metric missing")
        return out

    if c.mode == "true":
        ok = fv is True
        note = f"expected True, got {_fmt(fv)}"
    elif c.mode == "eq":
        if isinstance(c.value, float) or isinstance(fv, float):
            ok = abs(float(fv) - float(c.value)) <= max(c.abs_tol, 1e-9)
        else:
            ok = fv == c.value
        note = f"expected == {_fmt(c.value)}, got {_fmt(fv)}"
    elif c.mode == "ge":
        ok = float(fv) >= float(c.value) - c.abs_tol
        note = f"expected >= {_fmt(c.value)}, got {_fmt(fv)}"
    elif c.mode == "le":
        ok = float(fv) <= float(c.value) + c.abs_tol
        note = f"expected <= {_fmt(c.value)}, got {_fmt(fv)}"
    else:
        raise ValueError(f"unknown check mode {c.mode!r}")
    out.update(status="PASS" if ok else "FAIL", note=note)
    return out


# ---------------------------------------------------------------------------
# Per-kind check specs.  Invariants first (always run), then relative
# checks (run only on config match).
# ---------------------------------------------------------------------------

def checks_serving() -> List[Check]:
    return [
        # correctness invariants — the whole point of the bench A/Bs
        Check("outputs_identical_prefix_on_off", "true"),
        Check("packed.outputs_identical_packed_on_off", "true"),
        Check("speculative.outputs_match_nonspec", "true", if_present=True),
        Check("kv_sweep.int8_outputs_match_bf16", "true", if_present=True),
        # weight sweep: int8 weights must actually compress (codes +
        # fp32 scales land a bit above half of bf16 — gate at 0.6), and
        # greedy parity vs full precision is recorded, not hidden
        Check("weight_sweep.int8_weight_bytes_ratio_vs_bf16", "le",
              value=0.6, if_present=True),
        Check("weight_sweep.int8.weight_bytes_saved", "ge", value=1,
              if_present=True),
        Check("weight_sweep.int8_greedy_match_frac", "ge", value=0.0,
              if_present=True),
        Check("weight_sweep.int8_speedup_tokens_per_s", "rel",
              rel_tol=0.5, abs_tol=0.05, higher_better=True,
              if_present=True),
        # structural: token packing really packs — one (1, T) dispatch per
        # mixed iteration, and padding waste stays bounded
        Check("packed.packed_on.dispatches_per_iter", "eq", value=1.0,
              abs_tol=1e-6),
        Check("packed.packed_on.padded_token_frac", "le", value=0.25),
        Check("packed.packed_on.prefill_pad_frac", "eq", value=0.0,
              abs_tol=1e-6),
        # relative (config match only): loose — catch collapses, not drift
        Check("continuous_speedup_tokens_per_s", "rel", rel_tol=0.5,
              abs_tol=0.05, higher_better=True),
        Check("packed.tokens_per_s_ratio", "rel", rel_tol=0.5,
              abs_tol=0.05, higher_better=True),
        Check("continuous_prefix.prefix_hit_rate", "rel", rel_tol=0.5,
              abs_tol=0.01, higher_better=True),
        Check("continuous.dispatches_per_iter", "rel", rel_tol=0.0,
              abs_tol=1e-6, higher_better=False),
    ]


def checks_longprompt() -> List[Check]:
    return [
        Check("longprompt.outputs_identical_chunked_on_off", "true"),
        Check("outputs_identical_prefix_on_off", "true"),
        Check("packed.outputs_identical_packed_on_off", "true"),
        # chunked prefill exists to bound decode stalls behind long
        # prefills: tail ITL must improve vs the unchunked baseline
        # (abs_tol mirrors the 1.1x jitter slack of the CI smoke gate)
        Check("longprompt.itl_p99_improvement", "ge", value=1.0,
              abs_tol=0.1),
        Check("longprompt.chunked_on.dispatches_per_iter", "eq", value=1.0,
              abs_tol=1e-6),
        Check("longprompt.chunked_on.prefill_pad_frac", "eq", value=0.0,
              abs_tol=1e-6),
        Check("longprompt.chunked_on.padded_token_frac", "le", value=0.1),
        # the structural win is ~4.5x locally; a collapse below ~20% of
        # baseline signals a real regression even on noisy runners (the
        # >= 1.0 invariant above still gates absolute correctness)
        Check("longprompt.itl_p99_improvement", "rel", rel_tol=0.8,
              abs_tol=0.25, higher_better=True),
    ]


def checks_overload() -> List[Check]:
    return [
        # survivability invariants: every request reaches a terminal
        # state and contention never changes greedy outputs
        Check("overload.all_terminal", "true"),
        Check("overload.all_completed", "true"),
        Check("overload.outputs_identical_contended", "true"),
        # the contended leg must actually exercise the machinery
        Check("overload.contended.preemptions", "ge", value=1),
        Check("overload.contended.offloaded_pages", "ge", value=1),
        Check("overload.contended.restored_pages", "ge", value=1),
        Check("overload.contended.preemptions", "rel", rel_tol=1.0,
              abs_tol=2, higher_better=False),
    ]


KIND_CHECKS = {
    "serving": checks_serving,
    "longprompt": checks_longprompt,
    "overload": checks_overload,
}


def detect_kind(report: Dict[str, Any]) -> str:
    if "overload" in report:
        return "overload"
    if "longprompt" in report:
        return "longprompt"
    return "serving"


def diff(baseline: Dict[str, Any], fresh: Dict[str, Any],
         kind: Optional[str] = None) -> Dict[str, Any]:
    """Run all checks for ``kind`` (auto-detected from the fresh report
    when None) and return the verdict dict."""
    if kind is None or kind == "auto":
        kind = detect_kind(fresh)
    if kind not in KIND_CHECKS:
        raise ValueError(f"unknown bench kind {kind!r}")
    cfg_b = {k: baseline.get(k) for k in CONFIG_KEYS}
    cfg_f = {k: fresh.get(k) for k in CONFIG_KEYS}
    config_match = cfg_b == cfg_f
    results = [run_check(c, fresh, baseline, config_match)
               for c in KIND_CHECKS[kind]()]
    n_fail = sum(1 for r in results if r["status"] == "FAIL")
    return {
        "kind": kind,
        "config_match": config_match,
        "baseline_config": cfg_b,
        "fresh_config": cfg_f,
        "pass": n_fail == 0,
        "n_pass": sum(1 for r in results if r["status"] == "PASS"),
        "n_fail": n_fail,
        "n_skip": sum(1 for r in results if r["status"] == "SKIP"),
        "checks": results,
    }


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Diff a fresh serving_bench report against a committed "
                    "baseline; exit 1 on regression.")
    ap.add_argument("baseline", help="committed BENCH_*.json")
    ap.add_argument("fresh", help="fresh serving_bench report JSON")
    ap.add_argument("--kind", default="auto",
                    choices=["auto", "serving", "longprompt", "overload"])
    ap.add_argument("--verdict-out", default="",
                    help="write machine-readable verdict JSON here")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    verdict = diff(baseline, fresh, args.kind)

    print(f"bench_diff [{verdict['kind']}] baseline={args.baseline} "
          f"fresh={args.fresh}")
    print(f"  config match: {verdict['config_match']} "
          f"(relative checks {'enabled' if verdict['config_match'] else 'skipped'})")
    for r in verdict["checks"]:
        print(f"  [{r['status']:4s}] {r['mode']:4s} {r['path']}: {r['note']}")
    print(f"  {verdict['n_pass']} pass, {verdict['n_fail']} fail, "
          f"{verdict['n_skip']} skip")

    if args.verdict_out:
        with open(args.verdict_out, "w") as f:
            json.dump(verdict, f, indent=2)
        print(f"  wrote {args.verdict_out}")

    if not verdict["pass"]:
        print("REGRESSION: bench_diff failed")
        return 1
    print("OK: no regression detected")
    return 0


if __name__ == "__main__":
    sys.exit(main())
