"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun), derives
the three terms per (arch x input-shape x mesh):

  compute    = HLO_FLOPs_per_chip / peak_FLOPs        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_chip / HBM_bw            (819 GB/s)
  collective = collective_bytes_per_chip / link_bw    (50 GB/s ICI)

HLO_FLOPs/bytes come from the trip-count-aware HLO analyzer (per-chip SPMD
program), so per-chip values are exactly what the formulas need.  Also
reports MODEL_FLOPS / HLO_FLOPs (useful-compute fraction: catches remat and
redundant-compute waste) and the dominant term.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR,
                 mesh: Optional[str] = "16x16") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def terms(rec: dict) -> Optional[dict]:
    hlo = rec.get("hlo", {})
    if "flops" not in hlo:
        return None
    chips = rec["chips"]
    compute = hlo["flops"] / PEAK_FLOPS
    memory = hlo["bytes"] / HBM_BW
    coll = rec["collectives"]["total_bytes"] / LINK_BW
    dom = max((compute, "compute"), (memory, "memory"), (coll, "collective"))
    model_fl = rec["model_flops"]["flops"]
    hlo_global = hlo["flops"] * chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "dominant": dom[1], "dominant_s": dom[0],
        "model_flops": model_fl,
        "hlo_flops_global": hlo_global,
        "useful_frac": model_fl / hlo_global if hlo_global else 0.0,
        "peak_bytes_per_chip": rec.get("memory", {}).get(
            "peak_memory_in_bytes", 0),
        "fits_hbm": rec.get("memory", {}).get(
            "peak_memory_in_bytes", 0) < 16e9,
    }


def table(recs: List[dict]) -> List[dict]:
    out = []
    for r in recs:
        t = terms(r)
        if t:
            out.append(t)
    return out


def fmt_seconds(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def render_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute | memory | collective | dominant | "
           "useful% | fits HBM |\n|---|---|---|---|---|---|---|---|\n")
    lines = []
    for t in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {t['arch']} | {t['shape']} | {fmt_seconds(t['compute_s'])} "
            f"| {fmt_seconds(t['memory_s'])} "
            f"| {fmt_seconds(t['collective_s'])} | **{t['dominant']}** "
            f"| {100*t['useful_frac']:.0f}% "
            f"| {'y' if t['fits_hbm'] else 'NO'} |")
    return hdr + "\n".join(lines)


def main():
    rows = table(load_records())
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "useful_frac,fits_hbm")
    for t in rows:
        print(f"{t['arch']},{t['shape']},{t['mesh']},{t['compute_s']:.4g},"
              f"{t['memory_s']:.4g},{t['collective_s']:.4g},{t['dominant']},"
              f"{t['useful_frac']:.3f},{t['fits_hbm']}")
    return rows


if __name__ == "__main__":
    main()
