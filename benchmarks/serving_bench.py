"""Serving benchmark: bucket vs continuous batching vs prefix-cached.

Drives one request trace through the request-level paths of the engine
and reports tokens/s, per-request completion latency (p50/p99), and
padding/idle/prefill waste:

  * bucket:      DynamicBatcher -> generate_batch per bucket, every request
                 in a batch decodes until the batch's longest one finishes
  * continuous:  persistent decode slots + paged KV pool; admit on free
                 slot, retire at EOS (engine.serve_continuous)
  * continuous+prefix: the radix prefix cache maps shared prompt-prefix
                 pages zero-copy and prefills only each request's suffix

Two trace shapes:
  * mixed:  short-head/long-tail prompt lengths (the paper's Fig.-3
            observation), no intentional sharing
  * shared: N requests over --prefix-groups distinct system prompts —
            the multi-tenant workload prefix caching targets

``--kv-sweep`` additionally serves the same trace at kv_dtype bf16 and
int8 under an equal-bytes pool budget (int8 gets 2x the pages) and
records tokens/s, p50/p99, admission stalls and prefix evictions per
leg, plus greedy-output parity against a full-precision reference.

``--spec {ngram,draft}`` adds a speculative-decoding leg: the same trace
served with draft-verify decoding (prompt-lookup n-gram drafter, or the
model self-drafting for the "draft" smoke), recording acceptance rate,
tokens per forward, tokens/s — and greedy parity vs the non-speculative
continuous run, which must be bit-exact.

``--trace longprompt`` stresses the unified token-budget scheduler: one
``--long-prompt-len`` prompt arrives while short requests decode.  The
A/B leg serves it with chunked prefill OFF (whole-prompt admission — the
prompt's forward stalls every decode slot) and ON (budgeted chunks
interleaved with decode) and records TTFT / inter-token-latency
percentiles each way; greedy outputs must be bit-identical.

``--trace overload`` stresses the overload ladder: long prompts arrive
in three bursts against a page pool sized to ~1/3 of aggregate demand,
with LRU preemption and a host KV tier enabled.  The A/B leg compares
an uncontended run (pool = full demand) against the contended one:
every request must still complete with a terminal outcome, at least
one preemption must fire, greedy outputs must be bit-identical, and
the per-iteration allocator/host audit runs throughout.

Results are also written as machine-readable JSON (--out, default
``BENCH_serving.json``) so the perf trajectory is tracked across PRs.

Usage:
    PYTHONPATH=src python benchmarks/serving_bench.py \
        --arch unimo-text --requests 64 --max-batch 8 [--poisson 20] \
        [--trace shared --prefix-groups 8 --prefix-len 64]

CPU-friendly by default (reduced config, small trace); the same trace
shapes run unchanged on TPU.
"""
from __future__ import annotations

import argparse
import copy
import json
import time

import jax
import numpy as np

from repro.configs.registry import get_reduced, list_archs
from repro.core.engine import InferenceEngine
from repro.core.precision import get_policy
from repro.core.sampling import SamplingParams
from repro.core.scheduler import DynamicBatcher, Request, pad_batch


def build_trace(n: int, seed: int, vocab: int, max_prompt: int,
                max_new: int):
    """Mixed-length trace: short-head/long-tail prompt lengths (the
    paper's Fig.-3 observation) and per-request generation budgets."""
    rng = np.random.default_rng(seed)
    lens = np.clip(rng.lognormal(mean=2.5, sigma=0.8, size=n).astype(int) + 2,
                   3, max_prompt)
    news = rng.integers(max(2, max_new // 4), max_new + 1, size=n)
    reqs = [Request(uid=i,
                    tokens=[2] + list(map(int, rng.integers(
                        4, vocab, size=int(lens[i]) - 1))),
                    max_new_tokens=int(news[i]))
            for i in range(n)]
    return reqs


def build_shared_trace(n: int, seed: int, vocab: int, groups: int,
                       prefix_len: int, suffix_max: int, max_new: int):
    """Shared-prefix trace: ``n`` requests over ``groups`` distinct
    system prompts of ``prefix_len`` tokens, each with its own short
    suffix — the multi-tenant serving shape where cross-request KV reuse
    pays."""
    rng = np.random.default_rng(seed)
    prefixes = [[2] + list(map(int, rng.integers(4, vocab,
                                                 size=prefix_len - 1)))
                for _ in range(groups)]
    reqs = []
    for i in range(n):
        g = int(rng.integers(0, groups))
        suffix = list(map(int, rng.integers(
            4, vocab, size=int(rng.integers(2, suffix_max + 1)))))
        reqs.append(Request(uid=i, tokens=prefixes[g] + suffix,
                            max_new_tokens=int(rng.integers(
                                max(2, max_new // 2), max_new + 1))))
    return reqs


def build_longprompt_trace(n_short: int, seed: int, vocab: int,
                           long_len: int, max_new: int):
    """Adversarial chunked-prefill trace: ``n_short`` short prompts
    arrive at t=0 and decode steadily; ONE ``long_len``-token prompt
    arrives mid-decode.  Without chunked prefill its whole-prompt
    admission forward stalls every decoding slot at once — the
    inter-token-latency p99 spike this PR's unified scheduler removes.
    Returns (requests, arrivals)."""
    rng = np.random.default_rng(seed)
    reqs = [Request(uid=i,
                    tokens=[2] + list(map(int, rng.integers(
                        4, vocab, size=int(rng.integers(5, 12))))),
                    max_new_tokens=max_new)
            for i in range(n_short)]
    reqs.append(Request(uid=n_short,
                        tokens=[2] + list(map(int, rng.integers(
                            4, vocab, size=long_len - 1))),
                        max_new_tokens=max(4, max_new // 4)))
    arrivals = [0.0] * n_short + [0.2]
    return reqs, arrivals


def build_overload_trace(n: int, seed: int, vocab: int, max_prompt: int,
                         max_new: int):
    """Adversarial burst trace for the overload ladder: ``n`` requests
    with deliberately long prompts (upper half of the length range, so
    aggregate page demand is high) arriving in three tight waves.
    Returns (requests, arrivals)."""
    rng = np.random.default_rng(seed)
    reqs, arrivals = [], []
    waves = [0.0, 0.15, 0.3]
    for i in range(n):
        ln = int(rng.integers(max(3, max_prompt // 2), max_prompt + 1))
        reqs.append(Request(uid=i,
                            tokens=[2] + list(map(int, rng.integers(
                                4, vocab, size=ln - 1))),
                            max_new_tokens=max_new))
        arrivals.append(waves[(len(waves) * i) // n])
    return reqs, arrivals


def run_overload_ab(args, engine_factory, trace, sp, arrivals,
                    tracer=None):
    """Serve the burst trace uncontended (pool = aggregate demand) and
    contended (pool ~1/3 of demand, LRU preemption + host KV tier,
    per-iteration audit on) and compare: the contended run must preempt
    at least once yet finish every request with a terminal outcome and
    bit-identical greedy output — overload degrades latency, never
    results."""
    from repro.core.scheduler import TERMINAL_STATUSES
    ps = args.page_size
    pages_per_slot = -(-args.max_len // ps)
    demand = sum(min(-(-(len(r.tokens) + r.max_new_tokens) // ps),
                     pages_per_slot) for r in trace)
    contended_pool = args.num_pages or max(pages_per_slot + 2, demand // 3)
    legs, outs, outcomes = {}, {}, {}
    for name, kw in (
            ("uncontended", dict(num_pages=demand)),
            ("contended", dict(num_pages=contended_pool, preemption="lru",
                               host_kv_bytes=1 << 30, debug_audit=True))):
        eng = engine_factory()
        run_continuous(eng, copy.deepcopy(trace), sp,       # warm compile
                       page_size=ps, steps_per_sync=args.steps_per_sync,
                       max_batched_tokens=args.max_batched_tokens,
                       chunked_prefill=True, **kw)
        reqs = copy.deepcopy(trace)
        # trace only the contended leg — the run the timeline is FOR
        # (preempt/offload/restore events live there)
        legs[name] = run_continuous(
            eng, reqs, sp, page_size=ps,
            steps_per_sync=args.steps_per_sync, arrivals=arrivals,
            max_batched_tokens=args.max_batched_tokens,
            chunked_prefill=True,
            tracer=tracer if name == "contended" else None, **kw)
        legs[name]["num_pages"] = kw["num_pages"]
        outs[name] = [r.result for r in reqs]
        outcomes[name] = [r.outcome for r in reqs]
    contended = outcomes["contended"]
    return {
        "demand_pages": demand,
        "contended_pool_frac": round(contended_pool / demand, 3),
        "uncontended": legs["uncontended"],
        "contended": legs["contended"],
        "all_terminal": all(oc is not None
                            and oc.status in TERMINAL_STATUSES
                            for oc in contended),
        "all_completed": all(oc is not None and oc.status == "completed"
                             for oc in contended),
        "outputs_identical_contended":
            outs["contended"] == outs["uncontended"],
    }


def run_longprompt_ab(args, engine_factory, trace, sp, arrivals,
                      tracer=None):
    """Serve the longprompt trace with chunking OFF (bucketed
    whole-prompt admission) and ON (unified token-budget scheduler) and
    record the inter-token-latency tail each way — plus greedy parity,
    which must be bit-exact."""
    from repro.core.engine import (DEFAULT_MAX_BATCHED_TOKENS,
                                   packed_width_buckets)
    legs = {}
    outs = {}
    for name, on in (("chunked_off", False), ("chunked_on", True)):
        eng = engine_factory()
        run_continuous(eng, copy.deepcopy(trace), sp,       # warm compile
                       page_size=args.page_size, num_pages=args.num_pages,
                       steps_per_sync=args.steps_per_sync,
                       max_batched_tokens=args.max_batched_tokens,
                       chunked_prefill=on)
        if on:
            # stream widths depend on how many slots were decoding when
            # each chunk was cut — i.e. on arrival timing — so the trace
            # warm-up above may miss width buckets the measured run
            # hits.  Touch every packed stream width once (one lone
            # request per bucket packs as a single that-wide stream)
            # so the measured run never pays a mid-trace XLA compile.
            budget = args.max_batched_tokens or DEFAULT_MAX_BATCHED_TOKENS
            for i, w in enumerate(packed_width_buckets(budget)):
                if w > args.max_len - 4:
                    break
                # prefix matching must be off here: a warm request would
                # otherwise match the previous warm's cached context and
                # chunk only the suffix, skipping the width it exists
                # to compile
                eng.serve_continuous(
                    [Request(uid=10_000 + i, tokens=[2] * w,
                             max_new_tokens=2)],
                    sp, page_size=args.page_size,
                    num_pages=args.num_pages,
                    steps_per_sync=args.steps_per_sync,
                    max_batched_tokens=args.max_batched_tokens,
                    chunked_prefill=True, prefix_cache=False)
        eng.reset_prefix_cache()
        reqs = copy.deepcopy(trace)
        # trace only the measured chunked_on leg (the configuration the
        # timeline describes), never the warm-ups above
        legs[name] = run_continuous(
            eng, reqs, sp, page_size=args.page_size,
            num_pages=args.num_pages, steps_per_sync=args.steps_per_sync,
            arrivals=arrivals, max_batched_tokens=args.max_batched_tokens,
            chunked_prefill=on, tracer=tracer if on else None)
        outs[name] = [r.result for r in reqs]
    off_p99, on_p99 = (legs["chunked_off"]["itl_p99_s"],
                       legs["chunked_on"]["itl_p99_s"])
    return {
        **legs,
        "itl_p99_improvement": round(off_p99 / on_p99, 3)
        if on_p99 else float("nan"),
        "outputs_identical_chunked_on_off":
            outs["chunked_on"] == outs["chunked_off"],
    }


def run_packed_ab(args, engine_factory, trace, sp, arrivals):
    """Serve the trace on the unified scheduler with token-packed
    execution OFF (decode micro-step + one (1, W) dispatch per prefill
    chunk) and ON (the whole mixed iteration as ONE (1, T) ragged
    dispatch) — greedy parity must be bit-exact; the packed leg must
    make exactly one dispatch per mixed iteration with near-zero padded
    FLOPs."""
    from repro.core.engine import (DEFAULT_MAX_BATCHED_TOKENS,
                                   mixed_width_buckets,
                                   packed_width_buckets)
    legs = {}
    outs = {}
    for name, on in (("packed_off", False), ("packed_on", True)):
        eng = engine_factory()
        run_continuous(eng, copy.deepcopy(trace), sp,       # warm compile
                       page_size=args.page_size, num_pages=args.num_pages,
                       steps_per_sync=args.steps_per_sync,
                       max_batched_tokens=args.max_batched_tokens,
                       chunked_prefill=True, packed=on)
        # chunk widths (bucketed leg) and stream widths (packed leg)
        # both depend on arrival timing; touch every width bucket of
        # the leg's own ladder once so the measured run never pays a
        # mid-trace XLA compile
        budget = args.max_batched_tokens or DEFAULT_MAX_BATCHED_TOKENS
        ladder = (packed_width_buckets if on else mixed_width_buckets)
        for i, w in enumerate(ladder(budget)):
            if w > args.max_len - 4:
                break
            eng.serve_continuous(
                [Request(uid=20_000 + i, tokens=[2] * w,
                         max_new_tokens=2)],
                sp, page_size=args.page_size, num_pages=args.num_pages,
                steps_per_sync=args.steps_per_sync,
                max_batched_tokens=args.max_batched_tokens,
                chunked_prefill=True, packed=on, prefix_cache=False)
        eng.reset_prefix_cache()
        reqs = copy.deepcopy(trace)
        legs[name] = run_continuous(
            eng, reqs, sp, page_size=args.page_size,
            num_pages=args.num_pages, steps_per_sync=args.steps_per_sync,
            arrivals=arrivals, max_batched_tokens=args.max_batched_tokens,
            chunked_prefill=True, packed=on)
        outs[name] = [r.result for r in reqs]
    off, on = legs["packed_off"], legs["packed_on"]
    return {
        **legs,
        "tokens_per_s_ratio": round(
            on["tokens_per_s"] / off["tokens_per_s"], 3)
        if off["tokens_per_s"] else float("nan"),
        "itl_p99_improvement": round(
            off["itl_p99_s"] / on["itl_p99_s"], 3)
        if on["itl_p99_s"] else float("nan"),
        "outputs_identical_packed_on_off":
            outs["packed_on"] == outs["packed_off"],
    }


def run_bucket(engine: InferenceEngine, reqs, sp, arrivals=None) -> dict:
    """engine.serve semantics, instrumented per batch for latencies and
    padding accounting.  With ``arrivals``, requests join the batcher
    open-loop as they arrive (same workload the continuous path sees)."""
    batcher = DynamicBatcher(max_batch=engine.max_batch)
    incoming = sorted(zip(arrivals, reqs),
                      key=lambda p: p[0]) if arrivals else None
    if incoming is None:
        for r in reqs:
            batcher.add(r)
    arrival_of = dict(zip((r.uid for r in reqs), arrivals)) \
        if arrivals else {}
    t0 = time.perf_counter()
    lat, gen_tokens = {}, 0
    prompt_real = prompt_padded = 0
    decode_slot_steps = decode_live_steps = 0
    while True:
        if incoming:
            now = time.perf_counter() - t0
            while incoming and incoming[0][0] <= now:
                batcher.add(incoming.pop(0)[1])
        batch = batcher.next_batch()
        if batch is None:
            if not incoming:
                break
            time.sleep(min(0.01, max(0.0, incoming[0][0]
                                     - (time.perf_counter() - t0))))
            continue
        toks, lens = pad_batch(batch)
        max_new = max(r.max_new_tokens for r in batch.requests)
        gen = engine.generate_batch(toks, lens, max_new, sp)
        done_t = time.perf_counter() - t0
        prompt_real += int(lens.sum())
        prompt_padded += toks.size
        for i, r in enumerate(batch.requests):
            row = gen[i]
            r.result = [int(t) for t in row[row >= 0]][:r.max_new_tokens]
            # whole batch completes together; latency is arrival->done
            lat[r.uid] = done_t - arrival_of.get(r.uid, 0.0)
            gen_tokens += len(r.result)
            decode_live_steps += len(r.result)
        # every slot runs as many steps as the batch's longest request
        steps = int((gen >= 0).sum(axis=1).max(initial=0))
        decode_slot_steps += steps * batch.size
    wall = time.perf_counter() - t0
    lats = np.asarray([lat[r.uid] for r in reqs])
    return {
        "wall_s": round(wall, 3),
        "generated_tokens": gen_tokens,
        "tokens_per_s": round(gen_tokens / wall, 2),
        "p50_latency_s": round(float(np.percentile(lats, 50)), 3),
        "p99_latency_s": round(float(np.percentile(lats, 99)), 3),
        "prefill_pad_frac": round(1 - prompt_real / prompt_padded, 3)
        if prompt_padded else 0.0,
        "decode_idle_frac": round(
            1 - decode_live_steps / decode_slot_steps, 3)
        if decode_slot_steps else 0.0,
    }


def run_continuous(engine: InferenceEngine, reqs, sp, *, page_size,
                   steps_per_sync, arrivals=None, prefix_cache=False,
                   num_pages=None, spec=None, max_batched_tokens=None,
                   chunked_prefill=None, packed=None, preemption="off",
                   host_kv_bytes=None, debug_audit=False,
                   tracer=None) -> dict:
    t0 = time.perf_counter()
    _, m = engine.serve_continuous(reqs, sp, page_size=page_size,
                                   num_pages=num_pages,
                                   steps_per_sync=steps_per_sync,
                                   arrivals=arrivals,
                                   prefix_cache=prefix_cache, spec=spec,
                                   max_batched_tokens=max_batched_tokens,
                                   chunked_prefill=chunked_prefill,
                                   packed=packed, preemption=preemption,
                                   host_kv_bytes=host_kv_bytes,
                                   debug_audit=debug_audit, trace=tracer)
    wall = time.perf_counter() - t0
    out = {
        "wall_s": round(wall, 3),
        "generated_tokens": m.generated_tokens,
        "tokens_per_s": round(m.generated_tokens / wall, 2),
        "p50_latency_s": round(m.percentile_latency(50), 3),
        "p99_latency_s": round(m.percentile_latency(99), 3),
        "ttft_p50_s": round(m.ttft_p50, 4),
        "ttft_p99_s": round(m.ttft_p99, 4),
        "itl_p50_s": round(m.itl_p50, 4),
        "itl_p99_s": round(m.itl_p99, 4),
        "scheduler": m.scheduler,
        "max_batched_tokens": m.max_batched_tokens,
        "prefill_chunks": m.prefill_chunks,
        "prefill_pad_frac": round(m.prefill_pad_frac, 3),
        "decode_idle_frac": round(m.decode_idle_frac, 3),
        "mixed_iters": m.mixed_iters,
        "dispatches_per_iter": round(m.dispatches_per_iter, 3),
        "padded_token_frac": round(m.padded_token_frac, 3),
        "host_s": round(m.host_s, 3),
        "device_s": round(m.device_s, 3),
        "host_frac": round(m.host_frac, 3),
        "prefill_tokens": m.prefill_tokens,
        "prefix_hit_rate": round(m.prefix_hit_rate, 3),
        "prefix_matched_tokens": m.prefix_matched_tokens,
        "pages_shared": m.pages_shared,
        "cow_copies": m.cow_copies,
        "prefix_evicted_pages": m.prefix_evicted_pages,
        "kv_dtype": m.kv_dtype,
        "kv_pool_bytes": m.kv_pool_bytes,
        "kv_bytes_per_token": round(m.kv_bytes_per_token, 1),
        "weight_dtype": m.weight_dtype,
        "weight_bytes": m.weight_bytes,
        "weight_bytes_saved": m.weight_bytes_saved,
        "host_syncs": m.host_syncs,
        "peak_pages_in_use": m.peak_pages_in_use,
        "admission_stalls": m.admission_stalls,
        "rejected": m.rejected,
        "preemptions": m.preemptions,
        "resumed": m.resumed,
        "offloaded_pages": m.offloaded_pages,
        "restored_pages": m.restored_pages,
        "host_bytes_peak": m.host_bytes_peak,
        "timed_out": m.timed_out,
        "deadline_misses": m.deadline_misses,
        "outcomes": dict(sorted(m.outcome_counts.items())),
        "spec_mode": m.spec_mode,
        "spec_k": m.spec_k,
        "drafted_tokens": m.drafted_tokens,
        "accepted_tokens": m.accepted_tokens,
        "acceptance_rate": round(m.acceptance_rate, 3),
        "tokens_per_forward": round(m.tokens_per_forward, 3),
    }
    if tracer is not None:
        # reconcile the per-iteration timeline against the end-of-run
        # accounting: iteration device_s sums should match exactly (both
        # sides sum the same spans); host_s misses only pre/post-loop
        # setup, so its ratio is the acceptance gate's 5% check
        it = [e for e in tracer.events if e["kind"] == "iteration"]
        dev = sum(e["device_s"] for e in it)
        hst = sum(e["host_s"] for e in it)
        out["trace_iterations"] = len(it)
        out["trace_events"] = len(tracer.events)
        out["trace_device_span_s"] = round(dev, 4)
        out["trace_host_span_s"] = round(hst, 4)
        out["trace_device_recon"] = round(dev / m.device_s, 4) \
            if m.device_s else 1.0
        out["trace_host_recon"] = round(hst / m.host_s, 4) \
            if m.host_s else 1.0
    return out


def run_kv_sweep(args, cfg, params, base_policy, trace, sp, arrivals):
    """Same trace at kv_dtype bf16 vs int8 under an *equal-bytes* pool
    budget: bf16 gets ``budget`` pages, int8 gets 2x (half the bytes per
    K/V element; the small per-entry scale overhead is visible in the
    recorded kv_pool_bytes).  More pages means more concurrent slots and
    fewer prefix evictions, which is where the int8 throughput win comes
    from.  A full-precision (kv auto) leg provides the greedy-output
    reference."""
    import dataclasses
    slots = args.max_batch
    pages_per_slot = -(-args.max_len // args.page_size)
    # headroom above one slot's worth: a head-of-line request may need
    # the full pages_per_slot while its COW source page is pinned, and a
    # rejected request would make the output-parity comparison unfair
    budget = args.kv_budget_pages or max(pages_per_slot + 2,
                                         (slots * pages_per_slot) // 2)
    legs, outs = {}, {}
    for name, kv, pages in (("fp", "auto", budget),
                            ("bf16", "bf16", budget),
                            ("int8", "int8", 2 * budget)):
        pol = dataclasses.replace(base_policy, kv_dtype=kv)
        eng = InferenceEngine(cfg, params, policy=pol, max_batch=slots,
                              max_len=args.max_len)
        run_continuous(eng, copy.deepcopy(trace), sp,       # warm compile
                       page_size=args.page_size, num_pages=pages,
                       steps_per_sync=args.steps_per_sync,
                       prefix_cache=True)
        eng.reset_prefix_cache()                            # cold trie
        reqs = copy.deepcopy(trace)
        legs[name] = run_continuous(eng, reqs, sp,
                                    page_size=args.page_size,
                                    num_pages=pages,
                                    steps_per_sync=args.steps_per_sync,
                                    arrivals=arrivals, prefix_cache=True)
        legs[name]["num_pages"] = pages
        outs[name] = [r.result for r in reqs]
    speedup = (legs["int8"]["tokens_per_s"] / legs["bf16"]["tokens_per_s"]
               if legs["bf16"]["tokens_per_s"] else float("nan"))
    n = len(outs["fp"]) or 1
    return {
        "equal_bytes_budget_pages_bf16": budget,
        "fp_reference": legs["fp"],
        "bf16": legs["bf16"],
        "int8": legs["int8"],
        "int8_speedup_tokens_per_s": round(speedup, 3),
        "int8_outputs_match_fp": outs["int8"] == outs["fp"],
        # per-request greedy agreement with full precision — int8 KV
        # perturbs logits by ~absmax/254 per element, so requests whose
        # greedy margin sits below that can flip (see README precision)
        "int8_greedy_match_frac": round(sum(
            a == b for a, b in zip(outs["int8"], outs["fp"])) / n, 3),
        "int8_outputs_match_bf16": outs["int8"] == outs["bf16"],
    }


def run_weight_sweep(args, cfg, params, base_policy, trace, sp, arrivals):
    """Same trace at weights_dtype bf16 vs int8 (identical pool, slots
    and arrivals — weight storage is the only variable): int8 reads
    roughly half the weight bytes per matmul, which is where the
    decode-side win comes from on weight-bound hardware.  A
    full-precision (weights auto) leg provides the greedy-output
    reference; per-request agreement is recorded as a fraction, never
    asserted away — requests whose greedy margin sits below the
    per-channel quantization noise can flip (see README precision)."""
    import dataclasses
    legs, outs = {}, {}
    for name, wd in (("fp", "auto"), ("bf16", "bf16"), ("int8", "int8")):
        pol = dataclasses.replace(base_policy, weights_dtype=wd)
        eng = InferenceEngine(cfg, params, policy=pol,
                              max_batch=args.max_batch,
                              max_len=args.max_len)
        run_continuous(eng, copy.deepcopy(trace), sp,       # warm compile
                       page_size=args.page_size, num_pages=args.num_pages,
                       steps_per_sync=args.steps_per_sync,
                       prefix_cache=True)
        eng.reset_prefix_cache()                            # cold trie
        reqs = copy.deepcopy(trace)
        legs[name] = run_continuous(eng, reqs, sp,
                                    page_size=args.page_size,
                                    num_pages=args.num_pages,
                                    steps_per_sync=args.steps_per_sync,
                                    arrivals=arrivals, prefix_cache=True)
        outs[name] = [r.result for r in reqs]
    speedup = (legs["int8"]["tokens_per_s"] / legs["bf16"]["tokens_per_s"]
               if legs["bf16"]["tokens_per_s"] else float("nan"))
    bf16_bytes = legs["bf16"]["weight_bytes"]
    n = len(outs["fp"]) or 1
    return {
        "fp_reference": legs["fp"],
        "bf16": legs["bf16"],
        "int8": legs["int8"],
        "int8_speedup_tokens_per_s": round(speedup, 3),
        # codes + fp32 scales vs the same tensors at 2 bytes/element
        "int8_weight_bytes_ratio_vs_bf16": round(
            legs["int8"]["weight_bytes"] / bf16_bytes, 3)
        if bf16_bytes else float("nan"),
        "int8_outputs_match_fp": outs["int8"] == outs["fp"],
        "int8_greedy_match_frac": round(sum(
            a == b for a, b in zip(outs["int8"], outs["fp"])) / n, 3),
        "int8_outputs_match_bf16": outs["int8"] == outs["bf16"],
    }


def run_spec_leg(args, engine_factory, trace, sp, arrivals, baseline_reqs):
    """Serve the trace with draft-verify decoding and compare against the
    non-speculative continuous outputs: greedy parity must be bit-exact
    (the rejection sampler's guarantee), and the acceptance rate /
    tokens-per-forward quantify how much forward-count the drafter
    saved."""
    from repro.core.speculative import SpecConfig
    spec = SpecConfig(k=args.spec_k,
                      drafter=("ngram" if args.spec == "ngram"
                               else "draft_model"),
                      max_ngram=args.spec_ngram)
    eng = engine_factory()
    run_continuous(eng, copy.deepcopy(trace), sp,          # warm compile
                   page_size=args.page_size, num_pages=args.num_pages,
                   steps_per_sync=args.steps_per_sync,
                   prefix_cache=True, spec=spec)
    eng.reset_prefix_cache()
    reqs = copy.deepcopy(trace)
    leg = run_continuous(eng, reqs, sp, page_size=args.page_size,
                         num_pages=args.num_pages,
                         steps_per_sync=args.steps_per_sync,
                         arrivals=arrivals, prefix_cache=True, spec=spec)
    leg["outputs_match_nonspec"] = all(
        a.result == b.result for a, b in zip(reqs, baseline_reqs))
    return leg


def finish_tracing(report, tracer, out_path, fmt):
    """Export + schema-validate the measured run's trace and record the
    verdict under report['tracing'] ('trace' already names the workload
    shape)."""
    from repro.core.trace import export, validate_events
    errors = validate_events(tracer.events)
    paths = export(tracer, out_path, fmt)
    report["tracing"] = {
        "events": len(tracer.events),
        "dropped": tracer.dropped,
        "schema_valid": not errors,
        "errors": errors[:5],
        "paths": paths,
    }
    for p in paths:
        print(f"trace: {p}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="unimo-text", choices=list_archs())
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="bucket batch size == continuous decode slots")
    ap.add_argument("--max-new-tokens", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page pool size (default: slots * pages-per-slot"
                         "; give the radix cache headroom to retain "
                         "prefixes by sizing above the slot minimum)")
    ap.add_argument("--steps-per-sync", type=int, default=8)
    ap.add_argument("--max-batched-tokens", type=int, default=None,
                    help="per-iteration token budget of the unified "
                         "scheduler (decode + chunked-prefill tokens); "
                         "default: engine default (256)")
    ap.add_argument("--long-prompt-len", type=int, default=1024,
                    help="prompt length of the adversarial request in "
                         "--trace longprompt (max-len grows to fit)")
    ap.add_argument("--policy", default="fp32",
                    choices=["fp32", "bf16", "fp16"])
    ap.add_argument("--kv-dtype", default="auto",
                    choices=["auto", "bf16", "fp16", "int8"],
                    help="KV-pool storage dtype for the main runs")
    ap.add_argument("--kv-sweep", action="store_true",
                    help="also run the same trace at kv bf16 vs int8 "
                         "under an equal-bytes pool budget (int8 gets 2x "
                         "pages) and record the comparison")
    ap.add_argument("--kv-budget-pages", type=int, default=None,
                    help="bf16 page budget for --kv-sweep (int8 gets 2x); "
                         "default: half the slots' worth of pages")
    ap.add_argument("--weights-dtype", default="auto",
                    choices=["auto", "bf16", "fp16", "int8"],
                    help="serve-path weight storage dtype for the main "
                         "runs (int8 = quantized codes + per-channel "
                         "scales with fused-dequant matmuls)")
    ap.add_argument("--weight-sweep", action="store_true",
                    help="also run the same trace at weights bf16 vs "
                         "int8 (equal trace, pool and arrivals) and "
                         "record tokens/s, ITL p99, weight bytes and "
                         "greedy parity vs a full-precision reference")
    ap.add_argument("--spec", default="off",
                    choices=["off", "ngram", "draft"],
                    help="add a speculative-decoding leg: ngram = "
                         "prompt-lookup drafter (no extra weights); "
                         "draft = draft-model drafter (self-drafting "
                         "smoke: the target model drafts for itself, so "
                         "greedy acceptance is ~100%%)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per slot per verify step")
    ap.add_argument("--spec-ngram", type=int, default=3,
                    help="longest trailing n-gram the lookup drafter "
                         "matches")
    ap.add_argument("--poisson", type=float, default=None,
                    help="arrival rate (req/s) for an open-loop trace; "
                         "default: all requests arrive at t=0")
    ap.add_argument("--trace", default="mixed",
                    choices=["mixed", "shared", "longprompt", "overload"],
                    help="mixed: lognormal lengths; shared: N requests "
                         "over --prefix-groups shared system prompts; "
                         "longprompt: one --long-prompt-len prompt "
                         "arriving mid-decode (chunked-prefill A/B: ITL "
                         "p99 with the unified scheduler on vs off); "
                         "overload: bursty long prompts vs a pool ~1/3 "
                         "of demand (preemption + host-offload A/B: all "
                         "requests must complete bit-identically)")
    ap.add_argument("--prefix-groups", type=int, default=8)
    ap.add_argument("--prefix-len", type=int, default=64)
    ap.add_argument("--suffix-max", type=int, default=12)
    ap.add_argument("--out", default="BENCH_serving.json",
                    help="machine-readable results path ('' to skip)")
    ap.add_argument("--trace-out", default="",
                    help="write a serve-loop trace of the final measured "
                         "run of this shape (mixed/shared: the prefix "
                         "leg; longprompt: the chunked_on leg; overload: "
                         "the contended leg); '' = no tracing")
    ap.add_argument("--trace-format", default="both",
                    choices=["jsonl", "perfetto", "both"],
                    help="trace export format (both = <base>.jsonl + "
                         "<base>.perfetto.json)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    tracer = None
    if args.trace_out:
        from repro.core.trace import ServeTracer
        tracer = ServeTracer()

    cfg = get_reduced(args.arch)
    policy = get_policy(args.policy)
    if args.kv_dtype != "auto" or args.weights_dtype != "auto":
        import dataclasses
        policy = dataclasses.replace(policy, kv_dtype=args.kv_dtype,
                                     weights_dtype=args.weights_dtype)
    from repro.models import transformer as T
    params = T.init_params(jax.random.PRNGKey(0), cfg, policy)
    sp = SamplingParams()                                 # greedy

    def fresh_engine():
        return InferenceEngine(cfg, params, policy=policy,
                               max_batch=args.max_batch,
                               max_len=args.max_len)

    vocab = min(cfg.vocab_size, 800)
    if args.trace == "overload":
        # focused A/B: the standard bucket/continuous/prefix legs say
        # nothing about overload, so the gate runs only the ladder
        trace, ov_arrivals = build_overload_trace(
            args.requests, args.seed, vocab,
            args.max_len - args.max_new_tokens, args.max_new_tokens)
        report = {
            "arch": args.arch, "requests": args.requests,
            "slots": args.max_batch, "max_new": args.max_new_tokens,
            "trace": args.trace,
            "overload": run_overload_ab(args, fresh_engine, trace, sp,
                                        ov_arrivals, tracer=tracer),
        }
        if tracer is not None:
            finish_tracing(report, tracer, args.trace_out,
                           args.trace_format)
        print(json.dumps(report, indent=2))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(report, f, indent=2)
            print(f"wrote {args.out}")
        return

    if args.trace == "shared":
        trace = build_shared_trace(
            args.requests, args.seed, vocab, args.prefix_groups,
            min(args.prefix_len, args.max_len - args.max_new_tokens
                - args.suffix_max),
            args.suffix_max, args.max_new_tokens)
    elif args.trace == "longprompt":
        # context must hold the adversarial prompt plus its budget
        args.max_len = max(args.max_len,
                           args.long_prompt_len + args.max_new_tokens)
        trace, lp_arrivals = build_longprompt_trace(
            args.requests, args.seed, vocab, args.long_prompt_len,
            args.max_new_tokens)
    else:
        trace = build_trace(args.requests, args.seed, vocab,
                            args.max_len - args.max_new_tokens,
                            args.max_new_tokens)
    arrivals = None
    if args.trace == "longprompt":
        arrivals = lp_arrivals
    elif args.poisson:
        rng = np.random.default_rng(args.seed + 1)
        arrivals = list(np.cumsum(
            rng.exponential(1.0 / args.poisson, size=len(trace))))

    # warm up compilation on every path with the full trace shape set so
    # the numbers compare steady-state serving, not tracing time
    eng = fresh_engine()
    run_bucket(eng, copy.deepcopy(trace), sp)
    bucket = run_bucket(eng, copy.deepcopy(trace), sp, arrivals=arrivals)

    eng = fresh_engine()
    run_continuous(eng, copy.deepcopy(trace), sp, page_size=args.page_size, num_pages=args.num_pages,
                   steps_per_sync=args.steps_per_sync)
    cont_reqs = copy.deepcopy(trace)
    cont = run_continuous(eng, cont_reqs, sp,
                          page_size=args.page_size, num_pages=args.num_pages,
                          steps_per_sync=args.steps_per_sync,
                          arrivals=arrivals)

    eng = fresh_engine()
    run_continuous(eng, copy.deepcopy(trace), sp, page_size=args.page_size, num_pages=args.num_pages,
                   steps_per_sync=args.steps_per_sync, prefix_cache=True)
    # measured run starts from a COLD radix trie (warm compilation): all
    # sharing observed below happens within the measured trace itself
    eng.reset_prefix_cache()
    pfx_reqs = copy.deepcopy(trace)
    # the prefix leg is this shape's final measured full-stack run; on
    # longprompt shapes the timeline belongs to the chunked_on A/B leg
    pfx = run_continuous(eng, pfx_reqs, sp, page_size=args.page_size, num_pages=args.num_pages,
                         steps_per_sync=args.steps_per_sync,
                         arrivals=arrivals, prefix_cache=True,
                         tracer=tracer if args.trace != "longprompt"
                         else None)

    identical = all(a.result == b.result
                    for a, b in zip(cont_reqs, pfx_reqs))
    speedup = (cont["tokens_per_s"] / bucket["tokens_per_s"]
               if bucket["tokens_per_s"] else float("nan"))
    pfx_speedup = (pfx["tokens_per_s"] / cont["tokens_per_s"]
                   if cont["tokens_per_s"] else float("nan"))
    report = {
        "arch": args.arch, "requests": args.requests,
        "slots": args.max_batch, "max_new": args.max_new_tokens,
        "trace": args.trace, "poisson_rate": args.poisson,
        "prefix_groups": args.prefix_groups if args.trace == "shared"
        else None,
        "bucket": bucket, "continuous": cont,
        "continuous_prefix": pfx,
        "continuous_speedup_tokens_per_s": round(speedup, 3),
        "prefix_speedup_tokens_per_s": round(pfx_speedup, 3),
        "prefill_tokens_saved": cont["prefill_tokens"]
        - pfx["prefill_tokens"],
        "outputs_identical_prefix_on_off": identical,
    }
    # packed-vs-bucketed execution A/B on the unified scheduler: one
    # (1, T) dispatch per iteration vs decode micro-step + per-chunk
    # dispatches — bit-identical outputs, fewer dispatches, ~zero pad
    report["packed"] = run_packed_ab(args, fresh_engine, trace, sp,
                                     arrivals)
    if args.trace == "longprompt":
        report["longprompt"] = run_longprompt_ab(args, fresh_engine, trace,
                                                 sp, arrivals,
                                                 tracer=tracer)
    if args.spec != "off":
        leg = run_spec_leg(args, fresh_engine, trace, sp, arrivals,
                           cont_reqs)
        report["speculative"] = leg
        # like-for-like: the spec leg runs with the prefix cache on, so
        # its throughput baseline is the prefix leg, not the bare
        # continuous leg (outputs are bit-identical to both regardless)
        report["spec_speedup_tokens_per_s"] = round(
            leg["tokens_per_s"] / pfx["tokens_per_s"], 3) \
            if pfx["tokens_per_s"] else float("nan")
    if args.kv_sweep:
        report["kv_sweep"] = run_kv_sweep(args, cfg, params, policy,
                                          trace, sp, arrivals)
    if args.weight_sweep:
        report["weight_sweep"] = run_weight_sweep(args, cfg, params,
                                                  policy, trace, sp,
                                                  arrivals)
    if tracer is not None:
        finish_tracing(report, tracer, args.trace_out, args.trace_format)
    print(json.dumps(report, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
