"""Paper Table-1 reproduction: cumulative optimization stages.

The paper reports samples/s on its (proprietary) marketing-text workload:

    1 Baseline                            16.11
    2 + Fast transformer (fp16+KV+fused)  98.46
    3 + embedding layer pruning          125.32
    4 + multi-process parallel           144.45   (8.96x)

We reproduce the *stage structure and metric* on a synthetic Zipf workload
with a scaled UNIMO-text (same family: learned positions, LayerNorm, GELU,
vocab 12800, max_seq 512) sized so stage timings are measurable on the CPU
host.  Stage semantics:

  S1 baseline      : fp32, no KV cache (full forward per token), prompts
                     padded to the model max (512) — the paper's Figure-3
                     waste — sequential stages.
  S2 +fast-transformer : KV cache prefill/decode + half-precision policy +
                     buffer donation (P1).
  S3 +pruning      : vocabulary pruned to corpus coverage + position table
                     trimmed 512->128, padding buckets follow (P2).
  S4 +pipeline     : tokenize || infer || detokenize staged threads +
                     dynamic batching (P4).

Absolute numbers differ from the paper (CPU host, synthetic data); the
deliverable is the cumulative-ratio structure, recorded in EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs.base import LayerSpec, ModelConfig, uniform_stack
from repro.core import pruning as PR
from repro.core.engine import InferenceEngine
from repro.core.pipeline import run_pipelined, run_sequential
from repro.core.precision import BF16, FP32, Policy, get_policy
from repro.core.scheduler import DynamicBatcher
from repro.core.tokenizer import FastTokenizer
from repro.data.pipeline import synthetic_corpus
from repro.models import transformer as T

MAX_NEW = 12


def bench_config() -> ModelConfig:
    """Scaled UNIMO-text (same family as the paper's §3.1 model)."""
    return ModelConfig(
        name="unimo-text-bench", family="dense",
        source="paper §3.1, scaled for CPU benchmarking",
        d_model=256, num_heads=8, num_kv_heads=8, head_dim=32,
        d_ff=1024, vocab_size=12800,
        stacks=uniform_stack(6, LayerSpec()),
        pos_emb="learned", max_seq_len=512,
        activation="gelu", norm="layernorm", tie_embeddings=True,
        native_context=512)


def _workload(n: int, tok: FastTokenizer, seed: int = 0) -> List[str]:
    return synthetic_corpus(n, seed=seed, min_len=6, max_len=60)


def _run_stage(texts, tok, engine, *, pipelined: bool, buckets,
               max_batch: int = 8):
    t0 = time.perf_counter()
    runner = run_pipelined if pipelined else run_sequential
    # monkey-light: bucket control via engine-side batcher defaults
    import repro.core.scheduler as SCH
    old = SCH.DEFAULT_BUCKETS
    SCH.DEFAULT_BUCKETS = buckets
    try:
        res = runner(texts, tok, engine, max_new_tokens=MAX_NEW,
                     max_batch=max_batch)
    finally:
        SCH.DEFAULT_BUCKETS = old
    dt = time.perf_counter() - t0
    assert len(res) == len(texts)
    return dt


def run_table1(n_requests: int = 24, half: str = "bf16", seed: int = 0):
    """Returns list of (stage, seconds, samples_per_s, cum_speedup)."""
    cfg = bench_config()
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    corpus = synthetic_corpus(400, seed=seed + 1)
    tok = FastTokenizer.train(corpus, 2000)
    texts = _workload(n_requests, tok, seed=seed + 2)
    half_policy: Policy = get_policy(half)

    rows = []

    def record(name, engine, *, pipelined, buckets):
        # warm: full workload once so every bucket shape is compiled and
        # stage timings measure inference, not XLA compilation
        _run_stage(texts, tok, engine, pipelined=pipelined, buckets=buckets)
        dt = _run_stage(texts, tok, engine, pipelined=pipelined,
                        buckets=buckets)
        sps = n_requests / dt
        base = rows[0][2] if rows else sps
        rows.append((name, round(dt, 3), round(sps, 3),
                     round(sps / base, 2)))

    # S1: baseline — fp32, no KV cache, max-length padding, sequential
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=512 + MAX_NEW,
                          use_kv_cache=False, max_batch=8)
    record("baseline", eng, pipelined=False, buckets=(512,))

    # S2: + fast transformer (KV cache + half precision + donation)
    engine_kv = InferenceEngine(cfg, half_policy.cast_params(params),
                                policy=half_policy, max_len=512 + MAX_NEW,
                                max_batch=8)
    record("+fast_transformer", engine_kv, pipelined=False, buckets=(512,))

    # S3: + embedding pruning (vocab coverage + 512->128 position trim)
    freqs = tok.count_frequencies(corpus)
    p_pruned, cfg_pruned, maps = PR.prune_model(
        params, cfg, dict(freqs), coverage=0.999, new_max_len=128)
    engine_pr = InferenceEngine(cfg_pruned,
                                half_policy.cast_params(p_pruned),
                                policy=half_policy, max_len=128 + MAX_NEW,
                                max_batch=8, prune_maps=maps)
    record("+embedding_pruning", engine_pr, pipelined=False, buckets=(128,))

    # S4: + multi-process parallel processing (staged pipeline)
    record("+multiprocess_pipeline", engine_pr, pipelined=True,
           buckets=(128,))
    return rows


def main():
    rows = run_table1()
    print("stage,seconds,samples_per_s,cum_speedup")
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows


if __name__ == "__main__":
    main()
