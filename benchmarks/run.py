"""Benchmark harness entrypoint: one function per paper table/figure.

  * table1    — the paper's Table 1 (cumulative optimization speedups)
  * roofline  — §Roofline terms per (arch x shape) from the dry-run
  * kernels   — hot-path microbenchmarks (CPU reference numbers)

Prints ``name,us_per_call,derived`` style CSV sections.
"""
from __future__ import annotations

import traceback


def main() -> None:
    sections = []
    print("== table1: paper Table-1 cumulative speedups ==")
    try:
        from benchmarks import table1
        sections.append(("table1", table1.main()))
    except Exception:
        traceback.print_exc()

    print("\n== kernels: hot-path microbenchmarks ==")
    try:
        from benchmarks import kernels_bench
        sections.append(("kernels", kernels_bench.main()))
    except Exception:
        traceback.print_exc()

    print("\n== roofline: per (arch x shape) terms from dry-run ==")
    try:
        from benchmarks import roofline
        rows = roofline.main()
        if not rows:
            print("(no dry-run artifacts found — run "
                  "`python -m repro.launch.dryrun --all` first)")
        sections.append(("roofline", rows))
    except Exception:
        traceback.print_exc()


if __name__ == "__main__":
    main()
