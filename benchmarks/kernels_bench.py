"""Microbenchmarks for the hot-path implementations on the host CPU.

Wall-times here are CPU-reference numbers (the Pallas kernels target TPU
and are validated in interpret mode); what is *portable* is the relative
cost structure: chunked-flash vs naive attention memory behaviour, fused
rmsnorm vs unfused, KV-decode vs full recompute.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def _t(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_attention():
    rows = []
    rng = np.random.default_rng(0)
    for S in (512, 2048):
        B, H, D = 1, 8, 64
        q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
        k, v = q, q
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        ref = jax.jit(lambda q, k, v: L.attention_ref(
            q, k, v, pos, pos, window=None, scale=D ** -0.5))
        chk = jax.jit(lambda q, k, v: L.attention_chunked(
            q, k, v, pos, pos, window=None, scale=D ** -0.5, block=512))
        rows.append((f"attention_ref_S{S}", _t(ref, q, k, v), "naive"))
        rows.append((f"attention_chunked_S{S}", _t(chk, q, k, v),
                     "flash-style scan"))
    return rows


def bench_decode_vs_recompute():
    """The P1 KV-cache claim at kernel granularity."""
    rng = np.random.default_rng(0)
    B, S, H, D = 4, 1024, 8, 64
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = k
    q1 = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    qS = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    qp1 = jnp.full((B, 1), S - 1, jnp.int32)
    one = jax.jit(lambda q, k, v: L.attention_ref(
        q, k, v, qp1, pos, window=None, scale=D ** -0.5))
    full = jax.jit(lambda q, k, v: L.attention_ref(
        q, k, v, pos, pos, window=None, scale=D ** -0.5))
    rows = [("decode_1tok_kvcache", _t(one, q1, k, v), "P1 cached"),
            ("decode_full_recompute", _t(full, qS, k, v), "baseline")]
    return rows


def bench_rmsnorm():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4096, 1024)), jnp.float32)
    w = jnp.zeros((1024,))
    fused = jax.jit(lambda x, w: L.rmsnorm(x, w))
    rows = [("rmsnorm_rows4096_d1024", _t(fused, x, w), "fused-by-XLA")]
    return rows


def main():
    rows = bench_attention() + bench_decode_vs_recompute() + bench_rmsnorm()
    print("name,us_per_call,derived")
    for n, us, d in rows:
        print(f"{n},{us:.1f},{d}")
    return rows


if __name__ == "__main__":
    main()
