"""Sharding rules: parameter/activation/cache PartitionSpecs.

Baseline scheme (DESIGN.md §6):
  * Megatron tensor parallelism over the ``model`` axis: column-parallel
    up-projections (wq/wk/wv/wi/wg/wuq/wukv/w_up/w_in...), row-parallel
    down-projections (wo/w_down/w_out).
  * Expert parallelism: MoE expert stacks shard their expert axis over
    ``model``.
  * Data parallel over ``data`` (and ``pod`` across pods); optional FSDP
    shards the non-TP dim of large matrices over ``data``.
  * Caches: batch over (pod, data) when divisible; for single-stream
    long-context decode the KV sequence dim shards over ``data``
    (context parallelism).

Stacked (scan-repeated) parameters carry a leading repeats axis which is
never sharded — rules are expressed over *trailing* dims.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# rule: leaf name -> spec over trailing dims, with "fsdp" placeholders
_COL = (("fsdp", "model"), 2)            # (in, out): out dim TP
_ROW = (("model", "fsdp"), 2)            # (in, out): in dim TP
_REP2 = ((None, None), 2)

_RULES = {
    # attention / dense ffn / mla / mlstm / mamba projections
    "wq": _COL, "wk": _COL, "wv": _COL, "wi": _COL, "wg": _COL,
    "wuq": _COL, "wukv": _COL, "w_up": _COL, "w_gate": _COL, "w_in": _COL,
    "w_if": _REP2, "w_bc": (("model", None), 2), "w_dt": (("model", None), 2),
    "wo": _ROW, "w_down": _ROW, "w_out": _ROW,
    "wdq": (("fsdp", None), 2), "wdkv": (("fsdp", None), 2),
    "wkr": _REP2, "proj": _COL, "router": ((None, "model"), 2),
}

# MoE expert stacks: (E, in, out) trailing dims; expert axis over model.
_MOE_COL = (("model", "fsdp", None), 3)
_MOE_ROW = (("model", None, "fsdp"), 3)


def _leaf_spec(path: Tuple, leaf, fsdp: bool,
               replicate_attn: bool = False) -> P:
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = names[-1]
    ndim = len(leaf.shape)

    # tp_attn_guard (§Perf): when head counts don't divide the TP degree,
    # GSPMD reshuffles full activations around every per-head op; cheaper
    # to keep attention weights replicated and data-parallel.
    if replicate_attn and "attn" in names and name in ("wq", "wk", "wv",
                                                       "wo"):
        return P(*(None,) * ndim)

    def fill(tpl_ndim_pair):
        tpl, n = tpl_ndim_pair
        if ndim < n:
            return P()
        spec = tuple(("data" if a == "fsdp" and fsdp else
                      None if a == "fsdp" else a) for a in tpl)
        return P(*((None,) * (ndim - n) + spec))

    # embeddings ------------------------------------------------------------
    if name == "tokens":
        if ndim == 3:                         # (C, V, d) codebooks
            return P(None, "model", "data" if fsdp else None)
        return P("model", "data" if fsdp else None)
    if name == "heads":
        return P(None, "model", None)
    if name == "head":
        return fill(_COL)
    if name == "pos":
        return P()

    # MoE expert stacks: wi/wg/wo with expert + scan-repeat dims (ndim 4);
    # stacked dense FFN weights are ndim 3 and fall through to _RULES.
    if name in ("wi", "wg", "wo") and ndim >= 4 and "ffn" in names:
        return fill(_MOE_COL if name in ("wi", "wg") else _MOE_ROW)

    if name in _RULES:
        return fill(_RULES[name])
    return P()                                 # norms, biases, gates, conv, r


def param_pspecs(params_tree, cfg: ModelConfig, fsdp: bool = False,
                 mesh: Mesh = None):
    """Pytree of PartitionSpec matching ``params_tree`` (arrays or structs)."""
    from repro import perf_flags
    replicate_attn = False
    if mesh is not None and perf_flags.flag("tp_attn_guard"):
        tp = mesh.shape.get("model", 1)
        replicate_attn = cfg.num_heads % tp != 0
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = [_leaf_spec(path, leaf, fsdp, replicate_attn)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# concrete mesh for in-model sharding constraints (seq_parallel); set by
# the launcher that owns the mesh context.
_CURRENT_MESH: list = [None]


def set_current_mesh(mesh) -> None:
    _CURRENT_MESH[0] = mesh


def current_mesh():
    return _CURRENT_MESH[0]


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_parallel_size(mesh: Mesh) -> int:
    size = 1
    for a in batch_axes(mesh):
        size *= mesh.shape[a]
    return size


def batch_pspec(mesh: Mesh, batch_size: int, extra_dims: int = 1) -> P:
    """Shard dim0 over (pod, data) when divisible, else replicate."""
    dp = data_parallel_size(mesh)
    if batch_size % dp == 0 and batch_size >= dp:
        return P(batch_axes(mesh), *(None,) * extra_dims)
    return P(*(None,) * (extra_dims + 1))


def cache_pspecs(cache_tree, mesh: Mesh, batch_size: int):
    """Cache sharding: batch-parallel when possible; otherwise shard the
    KV sequence dim over ``data`` (context parallelism for long_500k)."""
    dp = data_parallel_size(mesh)
    batch_sharded = batch_size % dp == 0 and batch_size >= dp
    axes = batch_axes(mesh)

    def spec(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = names[-1]
        ndim = len(leaf.shape)
        # leading dim is scan repeats, dim1 is batch
        positional = name in ("k", "v", "ckv", "kr", "pos")
        if positional and ndim >= 3:
            # (R, B, S, ...): cache allocations are 256-multiples, so the
            # sequence dim always shards evenly.
            seq_ok = leaf.shape[2] % 256 == 0
            if batch_sharded:
                seq = "model" if seq_ok else None
                return P(None, axes, seq, *(None,) * (ndim - 3))
            seq = (("data", "model") if seq_ok else None)
            return P(None, None, seq, *(None,) * (ndim - 3))
        if batch_sharded:
            return P(None, axes, *(None,) * (ndim - 2))
        if name == "ssm" and ndim == 4:        # (R, B, d_inner, N)
            return P(None, None, "model", None)
        if name == "C" and ndim == 5:          # (R, B, H, dh, dh)
            return P(None, None, None, "model", None)
        return P(*(None,) * ndim)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(p, l) for p, l in flat])


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide evenly (e.g. a
    32001-row vocab on a 16-way model axis stays replicated)."""
    out = []
    for i, axes in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        alist = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in alist:
            size *= mesh.shape[a]
        out.append(axes if shape[i] % size == 0 else None)
    return P(*out)


def with_sharding(tree, specs, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree (dry-run inputs)."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, sanitize_spec(sp, s.shape, mesh))),
        tree, specs)
