"""Radix prefix cache — cross-request KV reuse over the paged pool.

The paper's serving wins come from reusing work across requests; PR-1's
paged KV pool makes the next reuse step natural: requests that share a
prompt prefix should share the prefix's *pages* instead of re-prefilling
them.  This module is the host-side index that makes that sharing safe:

  * a radix trie keyed on token ids, one node per KV *page span*
    (``page_size`` tokens; tail nodes may be partial),
  * page refcounts via :class:`~repro.core.continuous.PageAllocator`
    (the trie holds one reference per cached node; every request mapping
    a page holds another),
  * copy-on-write discipline: a page referenced by anyone else is never
    written — a request whose match ends inside a page gets a *fresh
    copy* of that partial tail page (``kv_cache.copy_pages``) and writes
    only into the copy,
  * LRU eviction of unreferenced leaves when the pool runs dry.

Sharing is only sound for layer families whose per-position KV is (a)
position-stable and (b) written exactly once at prefill.  That rules out
sliding-window/ring attention (pages are cyclically overwritten),
MLA-latent / recurrent / hybrid families (dense per-slot state, not
pages), and capacity-routed MoE (token dropping depends on batch
composition, so suffix-only prefill would change results).
:func:`shareable` is the per-layer opt-out gate; a model with any
opted-out layer serves correctly but never shares.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ATTN, MOE_FFN, ModelConfig


def shareable(cfg: ModelConfig, max_len: int) -> Optional[str]:
    """None if every layer family supports paged prefix sharing, else a
    human-readable reason naming the first opted-out layer family."""
    from repro.core import kv_cache as KV
    for stack in cfg.stacks:
        for spec in stack.pattern:
            if spec.mixer != ATTN:
                return (f"layer family '{spec.mixer}' keeps dense/ring "
                        f"state that cannot be shared across requests")
            if KV.effective_window(cfg, spec, max_len) is not None:
                return ("sliding-window attention cyclically overwrites "
                        "its pages (ring), so they cannot be shared")
            if spec.ffn == MOE_FFN:
                return ("capacity-routed MoE drops tokens as a function "
                        "of batch composition; suffix-only prefill would "
                        "change results")
    return None


@dataclass
class _Node:
    """One cached page span: ``tokens`` (<= page_size ids) backed by
    physical ``page``.  Partial nodes (len < page_size) are always
    leaves — a continuation within the same span extends the node in
    place (page swap), never adds children."""
    tokens: Tuple[int, ...]
    page: int
    parent: Optional["_Node"]
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)
    tick: int = 0
    pinned: bool = False


def _common_prefix(a: Sequence[int], b: Sequence[int]) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class RadixPrefixCache:
    """Trie over token-id page spans -> physical pages of the paged pool.

    The cache owns one allocator reference per resident node; ``match``
    does NOT take references (the scheduler increfs the pages it maps
    into a request).  Eviction only considers leaves whose page has no
    reference beyond the trie's own (i.e. refcount-0 from the requests'
    point of view) and never touches pinned nodes (``set_prefix``).
    """

    def __init__(self, allocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        self.root = _Node(tokens=(), page=-1, parent=None)
        self._tick = 0
        # cumulative, survives serve runs (per-run hit/match counters
        # live in ServeMetrics, which the engine fills at admission)
        self.evicted_pages = 0
        # host spill tier (set per serve call by the engine): evicted
        # full-page leaves demote into ``host_store`` via ``offload_fn``
        # instead of dropping, and the scheduler re-promotes them on a
        # match — the prefix cache outgrows device memory
        self.host_store = None         # HostKVStore or None
        self.offload_fn = None         # pages -> blob (device closure)
        self.spilled_pages = 0         # cumulative leaves demoted to host
        self.trace = None              # optional ServeTracer (set per serve)

    # -- introspection ------------------------------------------------------
    def _iter_nodes(self):
        stack = list(self.root.children.values())
        while stack:
            nd = stack.pop()
            yield nd
            stack.extend(nd.children.values())

    @property
    def num_nodes(self) -> int:
        return sum(1 for _ in self._iter_nodes())

    @property
    def resident_pages(self) -> List[int]:
        return [nd.page for nd in self._iter_nodes()]

    def _touch(self, node: _Node) -> None:
        self._tick += 1
        node.tick = self._tick

    def evictable_count(self) -> int:
        """Pages :meth:`evict` could free right now: unpinned leaves no
        live request maps (the scheduler's preemption-headroom bound)."""
        return sum(1 for nd in self._iter_nodes()
                   if not nd.children and not nd.pinned
                   and self.allocator.refcount(nd.page) == 1)

    def _span_key(self, node: _Node) -> tuple:
        """Host-tier key for a node: the full token path from the root
        (what a future admission will look up by)."""
        parts = []
        cur = node
        while cur is not None and cur.parent is not None:
            parts.append(cur.tokens)
            cur = cur.parent
        return ("trie", tuple(t for chunk in reversed(parts)
                              for t in chunk))

    # -- match --------------------------------------------------------------
    def match(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``tokens``.

        Returns (matched_len, pages) where ``pages`` cover token spans
        [0, page_size), [page_size, 2*page_size), ... of the match; the
        last page is *partial* when matched_len % page_size != 0 (or the
        final node itself is partial) — the caller must copy-on-write it
        before any use that involves further writes to that span.
        """
        node, m, pages = self.root, 0, []
        ps = self.page_size
        while m < len(tokens):
            chunk = tokens[m:m + ps]
            best, best_l = None, 0
            for child in node.children.values():
                l = _common_prefix(child.tokens, chunk)
                if l > best_l:
                    best, best_l = child, l
            if best is None:
                break
            self._touch(best)
            pages.append(best.page)
            m += best_l
            if best_l < ps:
                break                       # partial use / tail node: stop
            node = best
        return m, pages

    # -- insert -------------------------------------------------------------
    def insert(self, tokens: Sequence[int], pages: Sequence[int],
               valid_len: int, pin: bool = False) -> int:
        """Index ``tokens[:valid_len]`` whose KV lives in ``pages``
        (block-table order: pages[i] covers span [i*ps, (i+1)*ps)).

        Only spans the trie doesn't already cover take a new reference
        (incref); spans already cached keep the existing node (the
        caller's duplicate page is simply not retained).  A partial tail
        node that our tokens extend is updated in place: its page is
        swapped for ours (the old page loses the trie's reference; any
        active readers keep theirs).  Returns the number of pages newly
        retained by the trie.
        """
        node, i, pi, kept = self.root, 0, 0, 0
        ps = self.page_size
        while i < valid_len:
            chunk = tuple(tokens[i:min(i + ps, valid_len)])
            exact = node.children.get(chunk)
            if exact is not None:
                self._touch(exact)
                if pin:
                    exact.pinned = True
                if len(chunk) < ps:
                    break
                node, i, pi = exact, i + ps, pi + 1
                continue
            ext = cover = None
            for child in node.children.values():
                l = _common_prefix(child.tokens, chunk)
                if l == len(child.tokens) and l < len(chunk):
                    ext = child                 # child is a prefix of ours
                elif l == len(chunk) and l < len(child.tokens):
                    cover = child               # ours is a prefix of child
            if cover is not None:
                self._touch(cover)
                if pin:
                    cover.pinned = True
                break
            if ext is not None:
                # extend the partial node in place: swap to our page
                self.allocator.incref(pages[pi])
                self.allocator.decref(ext.page)
                del node.children[ext.tokens]
                ext.tokens = chunk
                ext.page = pages[pi]
                node.children[chunk] = ext
                child_node = ext
            else:
                self.allocator.incref(pages[pi])
                child_node = _Node(tokens=chunk, page=pages[pi], parent=node)
                node.children[chunk] = child_node
            kept += 1
            self._touch(child_node)
            if pin:
                child_node.pinned = True
            if len(chunk) < ps:
                break
            node, i, pi = child_node, i + ps, pi + 1
        return kept

    # -- eviction -----------------------------------------------------------
    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` pool pages by dropping LRU leaves whose
        page has no reference besides the trie's own.  Returns the number
        actually freed (may be less: pinned nodes and pages still mapped
        by live requests are never evicted)."""
        heap = [(nd.tick, id(nd), nd) for nd in self._iter_nodes()
                if not nd.children and not nd.pinned]
        heapq.heapify(heap)
        freed = 0
        spilled0 = self.spilled_pages
        while heap and freed < n_pages:
            _, _, nd = heapq.heappop(heap)
            if nd.children or nd.pinned or nd.parent is None:
                continue                        # stale heap entry
            if self.allocator.refcount(nd.page) > 1:
                continue                        # a live request maps it
            if (self.host_store is not None and self.offload_fn is not None
                    and len(nd.tokens) == self.page_size):
                # demote to host instead of dropping (full-page leaves
                # only: partial spans are not addressable by a
                # page-aligned promote lookup).  A refused put (host
                # full) degrades to the plain drop below.
                if self.host_store.put(self._span_key(nd),
                                       self.offload_fn([nd.page])):
                    self.spilled_pages += 1
            self.allocator.decref(nd.page)
            freed += 1
            self.evicted_pages += 1
            parent = nd.parent
            del parent.children[nd.tokens]
            nd.parent = None
            if (parent is not self.root and not parent.children
                    and not parent.pinned):
                heapq.heappush(heap, (parent.tick, id(parent), parent))
        if self.trace is not None and n_pages > 0:
            self.trace.emit_now("prefix_evict", requested=int(n_pages),
                                freed=int(freed),
                                spilled=int(self.spilled_pages - spilled0))
        return freed

    def unpin_all(self) -> None:
        for nd in self._iter_nodes():
            nd.pinned = False

    def clear(self) -> int:
        """Drop every node (regardless of pinning), releasing the trie's
        page references.  Pages mapped by live requests survive until
        those requests retire.  Returns the number of references
        released."""
        nodes = list(self._iter_nodes())
        for nd in nodes:
            self.allocator.decref(nd.page)
            nd.parent = None
        self.root.children.clear()
        return len(nodes)
