"""Token sampling for the decode loop."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 -> greedy
    top_k: int = 0                # 0 -> full distribution


def sample(logits, rng, sp: SamplingParams):
    """logits: (B, V) fp32 -> (B,) int32 token ids."""
    if sp.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / sp.temperature
    if sp.top_k:
        top_vals, _ = jax.lax.top_k(logits, sp.top_k)
        cutoff = top_vals[:, -1:]
        logits = jnp.where(logits >= cutoff, logits, -jnp.inf)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
