"""Token sampling for the decode loop + the speculative rejection sampler.

``sample`` draws one token per row from temperature / top-k / top-p
filtered logits.  ``speculative_verify`` is the acceptance rule of the
draft–verify loop (see ``core/speculative``): given the target model's
logits over a drafted window it returns how many drafted tokens survive
and the next token to emit, such that the emitted stream is distributed
exactly as non-speculative sampling from the same filtered distribution.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 -> greedy
    top_k: int = 0                # 0 -> full distribution
    top_p: float = 1.0            # 1 -> no nucleus filtering


def _filter_logits(logits, sp: SamplingParams):
    """Temperature / top-k / top-p (nucleus) filtering.  logits: (..., V)
    with sp.temperature > 0.  Removed tokens become -inf."""
    logits = logits / sp.temperature
    if sp.top_k:
        top_vals, _ = jax.lax.top_k(logits, sp.top_k)
        cutoff = top_vals[..., -1:]
        logits = jnp.where(logits >= cutoff, logits, -jnp.inf)
    if sp.top_p < 1.0:
        # nucleus: keep the smallest set of top tokens whose cumulative
        # probability reaches top_p.  A token is kept iff the cumulative
        # probability of strictly-higher-ranked tokens is < top_p (so the
        # token that crosses the threshold is included, and at least one
        # token always survives).
        sorted_logits = jnp.flip(jnp.sort(logits, axis=-1), axis=-1)
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum_before = jnp.cumsum(probs, axis=-1) - probs
        keep = cum_before < sp.top_p
        thresh = jnp.min(jnp.where(keep, sorted_logits, jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits >= thresh, logits, -jnp.inf)
    return logits


def target_probs(logits, sp: SamplingParams):
    """The exact distribution ``sample`` draws from: softmax of the
    filtered logits.  logits: (..., V) -> probs (..., V)."""
    return jax.nn.softmax(_filter_logits(logits.astype(jnp.float32), sp),
                          axis=-1)


def sample(logits, rng, sp: SamplingParams):
    """logits: (B, V) fp32 -> (B,) int32 token ids."""
    if sp.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(rng, _filter_logits(logits, sp),
                                  axis=-1).astype(jnp.int32)


def speculative_verify(logits, drafts, rng, sp: SamplingParams):
    """Rejection-sample a drafted window against the target logits.

    logits: (B, K+1, V) target logits at the K+1 speculated positions
    (position j scored the input [current_token, d_1..d_j]); drafts:
    (B, K) proposed continuation tokens.  Returns (accept_len (B,) int32
    in [0, K], next_token (B,) int32): drafts[:, :accept_len] are kept
    verbatim and ``next_token`` follows them.

    The drafters in ``core/speculative`` are deterministic, i.e. the
    proposal q_j is a point mass at d_{j+1}.  The standard speculative
    acceptance rule (accept x ~ q with probability min(1, p(x)/q(x)),
    else resample from norm(max(p - q, 0))) then reduces to: accept
    d_{j+1} with probability p_j(d_{j+1}); on rejection resample from
    p_j with d_{j+1} removed and renormalized.  This is distribution
    preserving at every position: P(emit x at j) = p_j(x)·[x = d] +
    (1 - p_j(d)) · p_j(x)·[x != d] / (1 - p_j(d)) = p_j(x).  With
    ``temperature == 0`` p_j is a point mass at argmax, so the rule
    becomes exact-match greedy: accept while argmax == draft, and the
    corrective token is the argmax at the first mismatch — bit-identical
    to non-speculative greedy decoding.
    """
    B, K = drafts.shape
    b_idx = jnp.arange(B)
    if sp.temperature <= 0.0:
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B, K+1)
        ok = pred[:, :K] == drafts
        accept_len = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1),
                             axis=1)
        next_token = pred[b_idx, accept_len]
        return accept_len.astype(jnp.int32), next_token
    p = target_probs(logits, sp)                               # (B, K+1, V)
    p_draft = jnp.take_along_axis(
        p[:, :K], drafts[..., None], axis=-1)[..., 0]          # (B, K)
    u_key, r_key = jax.random.split(rng)
    u = jax.random.uniform(u_key, (B, K))
    ok = u < p_draft
    accept_len = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
    # residual at the first rejected position: p with the rejected draft
    # token removed, renormalized (the point-mass proposal's max(p-q, 0));
    # when every draft is accepted the bonus position's p is unfiltered.
    p_next = p[b_idx, accept_len]                              # (B, V)
    rejected = accept_len < K
    rej_tok = drafts[b_idx, jnp.minimum(accept_len, K - 1)]
    hole = jax.nn.one_hot(rej_tok, p.shape[-1], dtype=bool)
    p_next = jnp.where(rejected[:, None] & hole, 0.0, p_next)
    total = jnp.sum(p_next, axis=-1, keepdims=True)
    # degenerate residual (all mass was on the rejected token — cannot
    # happen with exact arithmetic since then it would have been
    # accepted w.p. 1, but guard float round-off): fall back to p.
    p_next = jnp.where(total > 0.0, p_next, p[b_idx, accept_len])
    next_token = jax.random.categorical(
        r_key, jnp.log(jnp.maximum(p_next, 1e-38)), axis=-1)
    return accept_len.astype(jnp.int32), next_token.astype(jnp.int32)
