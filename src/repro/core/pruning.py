"""Embedding-layer pruning — paper pillar P2.

Two transforms, exactly as in the paper §3.2:

  1. **Vocabulary pruning**: keep only high-frequency tokens (from corpus
     statistics), shrink the token-embedding matrix (and untied LM head)
     accordingly, and remap ids.  Out-of-keep-set tokens map to <unk>.
     The paper trims UNIMO's 12800-token vocabulary; we generalize to every
     assigned architecture (151936 / 256000 / 262144-row embeddings are the
     strongest case: at 32k kept tokens gemma3's embedding shrinks 8x).

  2. **Position-table trimming**: for learned-position models, slice the
     position-embedding matrix to the serving context (the paper's
     512x1024 -> 128x1024).  RoPE/sinusoidal archs have no table — the
     transform is a documented no-op for them (DESIGN.md §4).

Both are *functional* transforms: (params, cfg) -> (params', cfg', maps).
Invariant (tested): logits over kept tokens are bit-identical to the
unpruned model's logits at those token positions.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.tokenizer import SPECIALS


@dataclass
class PruneMaps:
    """Id remapping produced by vocabulary pruning."""

    keep_ids: np.ndarray          # (V_new,) old ids kept, ascending
    old_to_new: np.ndarray        # (V_old,) new id, or UNK's new id
    new_to_old: np.ndarray        # (V_new,) inverse

    @property
    def new_vocab(self) -> int:
        return len(self.keep_ids)


def select_keep_ids(freqs: Dict[int, int], vocab_size: int, *,
                    max_vocab: Optional[int] = None,
                    coverage: Optional[float] = None,
                    always_keep: Sequence[int] = (0, 1, 2, 3)) -> np.ndarray:
    """Pick the token ids to keep, by budget or by corpus coverage."""
    assert (max_vocab is None) != (coverage is None), \
        "specify exactly one of max_vocab / coverage"
    counts = np.zeros(vocab_size, np.int64)
    for tid, c in freqs.items():
        if 0 <= tid < vocab_size:
            counts[tid] = c
    order = np.argsort(-counts, kind="stable")
    if coverage is not None:
        csum = np.cumsum(counts[order])
        total = max(csum[-1], 1)
        cut = int(np.searchsorted(csum / total, coverage) + 1)
        chosen = order[:cut]
    else:
        chosen = order[:max_vocab]
    keep = np.union1d(np.asarray(always_keep, np.int64),
                      chosen[counts[chosen] > 0] if coverage is not None
                      else chosen)
    return np.sort(keep)


def build_maps(keep_ids: np.ndarray, vocab_size: int,
               unk_id: int = 1) -> PruneMaps:
    keep_ids = np.sort(np.asarray(keep_ids, np.int64))
    assert unk_id in keep_ids, "UNK must be kept"
    old_to_new = np.full(vocab_size, -1, np.int64)
    old_to_new[keep_ids] = np.arange(len(keep_ids))
    unk_new = int(old_to_new[unk_id])
    old_to_new[old_to_new < 0] = unk_new
    return PruneMaps(keep_ids=keep_ids, old_to_new=old_to_new,
                     new_to_old=keep_ids.copy())


def prune_vocab(params, cfg: ModelConfig, maps: PruneMaps):
    """Gather kept rows out of the embedding (and untied head) matrices."""
    keep = jnp.asarray(maps.keep_ids)
    new_embed = dict(params["embed"])
    if cfg.num_codebooks:
        new_embed["tokens"] = params["embed"]["tokens"][:, keep]
        if "heads" in new_embed:
            new_embed["heads"] = params["embed"]["heads"][:, keep]
    else:
        new_embed["tokens"] = params["embed"]["tokens"][keep]
        if not cfg.tie_embeddings:
            new_embed["head"] = params["embed"]["head"][:, keep]
    new_params = dict(params)
    new_params["embed"] = new_embed
    new_cfg = cfg.replace(vocab_size=maps.new_vocab)
    return new_params, new_cfg


def trim_positions(params, cfg: ModelConfig, new_max_len: int):
    """The paper's 512x1024 -> 128x1024 position-table trim."""
    if cfg.pos_emb != "learned":
        return params, cfg          # RoPE/sinusoidal: documented no-op
    new_params = dict(params)
    new_embed = dict(params["embed"])
    new_embed["pos"] = params["embed"]["pos"][:new_max_len]
    new_params["embed"] = new_embed
    return new_params, cfg.replace(max_seq_len=new_max_len)


def prune_model(params, cfg: ModelConfig, freqs: Dict[int, int], *,
                max_vocab: Optional[int] = None,
                coverage: Optional[float] = None,
                new_max_len: Optional[int] = None):
    """Full P2 transform. Returns (params', cfg', maps)."""
    keep = select_keep_ids(freqs, cfg.vocab_size, max_vocab=max_vocab,
                           coverage=coverage)
    maps = build_maps(keep, cfg.vocab_size)
    params, cfg = prune_vocab(params, cfg, maps)
    if new_max_len is not None:
        params, cfg = trim_positions(params, cfg, new_max_len)
    return params, cfg, maps


def remap_tokens(tokens: np.ndarray, maps: PruneMaps) -> np.ndarray:
    """Map old-id token arrays into the pruned id space."""
    return maps.old_to_new[np.asarray(tokens)]


def unmap_tokens(tokens: np.ndarray, maps: PruneMaps) -> np.ndarray:
    """Map pruned-space ids back to original ids (for detokenization)."""
    return maps.new_to_old[np.asarray(tokens)]
