"""KV / state caches — paper pillar P1 (the K-V cache mechanism, Fig. 2).

The paper caches attention K/V to eliminate recomputation during
autoregressive decoding.  Here the idea is generalized into a *state cache*
abstraction covering every assigned architecture family:

  * full attention   -> (B, S_max, H_kv, D) K/V ring-less cache
  * sliding window   -> (B, W, H_kv, D) ring buffer (bounded memory)
  * MLA (DeepSeek)   -> compressed latent (B, S_max, kv_rank) + shared rope key
  * mLSTM / sLSTM    -> O(1) recurrent matrix/scalar memory
  * hybrid (Hymba)   -> window ring + SSM state + conv state

Every positional cache carries an explicit ``pos`` array (absolute token
position per cache slot, -1 = empty), which makes attention masks exact for
ring buffers and padded batches alike.

All update functions are functional (return a new cache pytree); the decode
step donates the cache buffers (XLA buffer donation = the paper's "memory
reuse"), so on device the update is in-place.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, HYBRID, MLA, MLSTM, SLSTM, LayerSpec,
                                ModelConfig)

# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def layer_cache_shape(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_len: int, dtype) -> dict:
    """Abstract (ShapeDtypeStruct-friendly) cache for one layer."""
    hd = cfg.resolved_head_dim
    window = effective_window(cfg, spec, max_len)
    # +1 "dump" slot: prefill padding tokens scatter there (marked pos=-1),
    # so ragged batches never evict live ring entries.  The allocation is
    # rounded up to a multiple of 256 so the sequence dim shards evenly
    # over the mesh (ring arithmetic uses shape-1; entries between the
    # window and the ring age out via the position mask).
    s = (min(window, max_len) if window else max_len) + 1
    s = -(-s // 256) * 256 if s > 256 else s

    def z(shape, dt=dtype):
        return jnp.zeros(shape, dt)

    if spec.mixer == ATTN:
        return {"k": z((batch, s, cfg.num_kv_heads, hd)),
                "v": z((batch, s, cfg.num_kv_heads, hd)),
                "pos": jnp.full((batch, s), -1, jnp.int32)}
    if spec.mixer == MLA:
        m = cfg.mla
        return {"ckv": z((batch, s, m.kv_lora_rank)),
                "kr": z((batch, s, m.rope_head_dim)),
                "pos": jnp.full((batch, s), -1, jnp.int32)}
    if spec.mixer == MLSTM:
        dh = (2 * cfg.d_model) // cfg.num_heads    # mLSTM runs at 2x width
        return {"C": z((batch, cfg.num_heads, dh, dh), jnp.float32),
                "n": z((batch, cfg.num_heads, dh), jnp.float32),
                "m": z((batch, cfg.num_heads), jnp.float32)}
    if spec.mixer == SLSTM:
        dh = cfg.d_model // cfg.num_heads
        return {"c": z((batch, cfg.num_heads, dh), jnp.float32),
                "n": z((batch, cfg.num_heads, dh), jnp.float32),
                "h": z((batch, cfg.num_heads, dh), jnp.float32),
                "m": z((batch, cfg.num_heads), jnp.float32)}
    if spec.mixer == HYBRID:
        ssm = cfg.ssm
        d_inner = ssm.expand * cfg.d_model
        out = {"k": z((batch, s, cfg.num_kv_heads, hd)),
               "v": z((batch, s, cfg.num_kv_heads, hd)),
               "pos": jnp.full((batch, s), -1, jnp.int32),
               "ssm": z((batch, d_inner, ssm.state_size), jnp.float32),
               "conv": z((batch, ssm.conv_size - 1, d_inner))}
        return out
    raise ValueError(spec.mixer)


def effective_window(cfg: ModelConfig, spec: LayerSpec,
                     max_len: int) -> Optional[int]:
    """Layer window, with the beyond-paper long-context override applied to
    global attention layers when serving beyond the native context."""
    w = spec.window
    if (w is None and spec.mixer in (ATTN, MLA)
            and cfg.long_context_override is not None
            and max_len > cfg.native_context):
        w = cfg.long_context_override
    return w


# ---------------------------------------------------------------------------
# Attention-cache updates
# ---------------------------------------------------------------------------


def write_prefill(cache: dict, new: dict, positions) -> dict:
    """Write a full prompt into a (possibly ring) positional cache.

    new: {"k": (B,S,H,D), ...} values aligned with ``positions`` (B,S); a
    position of -1 marks right-padding and is routed to the dump slot.
    For ring caches (ring size W < S) only the last W tokens land.
    """
    out = dict(cache)
    ring = cache["pos"].shape[1] - 1                           # last = dump
    B, S = positions.shape
    take = min(S, ring)
    # per-row: the last `take` *valid* tokens (positions are arange-based or
    # -1 for right-padding, so valid count = max(pos)+1).
    valid = jnp.maximum(positions.max(axis=1) + 1, 0)          # (B,)
    start = jnp.clip(valid - take, 0, S - take)
    idx = start[:, None] + jnp.arange(take)[None, :]           # (B, take)
    b_idx = jnp.arange(B)[:, None]
    pos_w = positions[b_idx, idx]
    slots = jnp.where(pos_w >= 0, pos_w % ring, ring)          # (B, take)
    for key, val in new.items():
        out[key] = cache[key].at[b_idx, slots].set(
            val[b_idx, idx].astype(cache[key].dtype))
    out["pos"] = cache["pos"].at[b_idx, slots].set(pos_w)
    return out


def write_decode(cache: dict, new: dict, lengths) -> dict:
    """Write one token per slot at absolute position ``lengths`` (B,)."""
    out = dict(cache)
    ring = cache["pos"].shape[1] - 1
    slots = lengths % ring
    b_idx = jnp.arange(cache["pos"].shape[0])
    for key, val in new.items():
        out[key] = cache[key].at[b_idx, slots].set(
            val[:, 0].astype(cache[key].dtype))
    out["pos"] = cache["pos"].at[b_idx, slots].set(lengths)
    return out


def cache_mask(cache_pos, q_pos, window: Optional[int]):
    """(B,Sq,Sk) bool mask from stored absolute positions.

    Empty slots (pos == -1) are never attended; ring overwrite correctness
    follows from the stored positions themselves.
    """
    valid = cache_pos[:, None, :] >= 0
    m = (cache_pos[:, None, :] <= q_pos[:, :, None]) & valid
    if window is not None:
        m &= cache_pos[:, None, :] > (q_pos[:, :, None] - window)
    return m
