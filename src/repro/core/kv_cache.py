"""KV / state caches — paper pillar P1 (the K-V cache mechanism, Fig. 2).

The paper caches attention K/V to eliminate recomputation during
autoregressive decoding.  Here the idea is generalized into a *state cache*
abstraction covering every assigned architecture family:

  * full attention   -> (B, S_max, H_kv, D) K/V ring-less cache
  * sliding window   -> (B, W, H_kv, D) ring buffer (bounded memory)
  * MLA (DeepSeek)   -> compressed latent (B, S_max, kv_rank) + shared rope key
  * mLSTM / sLSTM    -> O(1) recurrent matrix/scalar memory
  * hybrid (Hymba)   -> window ring + SSM state + conv state

Every positional cache carries an explicit ``pos`` array (absolute token
position per cache slot, -1 = empty), which makes attention masks exact for
ring buffers and padded batches alike.

All update functions are functional (return a new cache pytree); the decode
step donates the cache buffers (XLA buffer donation = the paper's "memory
reuse"), so on device the update is in-place.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, HYBRID, MLA, MLSTM, SLSTM, LayerSpec,
                                ModelConfig)

# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------


def layer_cache_shape(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_len: int, dtype) -> dict:
    """Abstract (ShapeDtypeStruct-friendly) cache for one layer."""
    hd = cfg.resolved_head_dim
    window = effective_window(cfg, spec, max_len)
    # +1 "dump" slot: prefill padding tokens scatter there (marked pos=-1),
    # so ragged batches never evict live ring entries.  The allocation is
    # rounded up to a multiple of 256 so the sequence dim shards evenly
    # over the mesh (ring arithmetic uses shape-1; entries between the
    # window and the ring age out via the position mask).
    s = (min(window, max_len) if window else max_len) + 1
    s = -(-s // 256) * 256 if s > 256 else s

    def z(shape, dt=dtype):
        return jnp.zeros(shape, dt)

    if spec.mixer == ATTN:
        return {"k": z((batch, s, cfg.num_kv_heads, hd)),
                "v": z((batch, s, cfg.num_kv_heads, hd)),
                "pos": jnp.full((batch, s), -1, jnp.int32)}
    if spec.mixer == MLA:
        m = cfg.mla
        return {"ckv": z((batch, s, m.kv_lora_rank)),
                "kr": z((batch, s, m.rope_head_dim)),
                "pos": jnp.full((batch, s), -1, jnp.int32)}
    if spec.mixer == MLSTM:
        dh = (2 * cfg.d_model) // cfg.num_heads    # mLSTM runs at 2x width
        return {"C": z((batch, cfg.num_heads, dh, dh), jnp.float32),
                "n": z((batch, cfg.num_heads, dh), jnp.float32),
                "m": z((batch, cfg.num_heads), jnp.float32)}
    if spec.mixer == SLSTM:
        dh = cfg.d_model // cfg.num_heads
        return {"c": z((batch, cfg.num_heads, dh), jnp.float32),
                "n": z((batch, cfg.num_heads, dh), jnp.float32),
                "h": z((batch, cfg.num_heads, dh), jnp.float32),
                "m": z((batch, cfg.num_heads), jnp.float32)}
    if spec.mixer == HYBRID:
        ssm = cfg.ssm
        d_inner = ssm.expand * cfg.d_model
        out = {"k": z((batch, s, cfg.num_kv_heads, hd)),
               "v": z((batch, s, cfg.num_kv_heads, hd)),
               "pos": jnp.full((batch, s), -1, jnp.int32),
               "ssm": z((batch, d_inner, ssm.state_size), jnp.float32),
               "conv": z((batch, ssm.conv_size - 1, d_inner))}
        return out
    raise ValueError(spec.mixer)


def effective_window(cfg: ModelConfig, spec: LayerSpec,
                     max_len: int) -> Optional[int]:
    """Layer window, with the beyond-paper long-context override applied to
    global attention layers when serving beyond the native context."""
    w = spec.window
    if (w is None and spec.mixer in (ATTN, MLA)
            and cfg.long_context_override is not None
            and max_len > cfg.native_context):
        w = cfg.long_context_override
    return w


# ---------------------------------------------------------------------------
# Attention-cache updates
# ---------------------------------------------------------------------------


def write_prefill(cache: dict, new: dict, positions) -> dict:
    """Write a full prompt into a (possibly ring) positional cache.

    new: {"k": (B,S,H,D), ...} values aligned with ``positions`` (B,S); a
    position of -1 marks right-padding and is routed to the dump slot.
    For ring caches (ring size W < S) only the last W tokens land.
    """
    out = dict(cache)
    ring = cache["pos"].shape[1] - 1                           # last = dump
    B, S = positions.shape
    take = min(S, ring)
    # per-row: the last `take` *valid* tokens (positions are arange-based or
    # -1 for right-padding, so valid count = max(pos)+1).
    valid = jnp.maximum(positions.max(axis=1) + 1, 0)          # (B,)
    start = jnp.clip(valid - take, 0, S - take)
    idx = start[:, None] + jnp.arange(take)[None, :]           # (B, take)
    b_idx = jnp.arange(B)[:, None]
    pos_w = positions[b_idx, idx]
    slots = jnp.where(pos_w >= 0, pos_w % ring, ring)          # (B, take)
    for key, val in new.items():
        out[key] = cache[key].at[b_idx, slots].set(
            val[b_idx, idx].astype(cache[key].dtype))
    out["pos"] = cache["pos"].at[b_idx, slots].set(pos_w)
    return out


def write_decode(cache: dict, new: dict, lengths) -> dict:
    """Write one token per slot at absolute position ``lengths`` (B,)."""
    out = dict(cache)
    ring = cache["pos"].shape[1] - 1
    slots = lengths % ring
    b_idx = jnp.arange(cache["pos"].shape[0])
    for key, val in new.items():
        out[key] = cache[key].at[b_idx, slots].set(
            val[:, 0].astype(cache[key].dtype))
    out["pos"] = cache["pos"].at[b_idx, slots].set(lengths)
    return out


def cache_mask(cache_pos, q_pos, window: Optional[int]):
    """(B,Sq,Sk) bool mask from stored absolute positions.

    Empty slots (pos == -1) are never attended; ring overwrite correctness
    follows from the stored positions themselves.
    """
    valid = cache_pos[:, None, :] >= 0
    m = (cache_pos[:, None, :] <= q_pos[:, :, None]) & valid
    if window is not None:
        m &= cache_pos[:, None, :] > (q_pos[:, :, None] - window)
    return m


# ---------------------------------------------------------------------------
# Paged (block-table) KV layout — continuous-batching serving path
# ---------------------------------------------------------------------------
#
# Instead of a dense (B, S_max, H, D) cache per slot, K/V live in a shared
# pool of fixed-size pages:
#
#   pk / pv : (P, page, H_kv, D)   physical page pool (per layer)
#   ppos    : (P, page)            absolute position per entry, -1 = empty
#
# plus one *global* block table (B_slots, pages_per_slot) of physical page
# ids shared by every attention layer (page id p belongs to the same request
# in all layers' pools).  Page P-1 is the reserved "dump" page: writes from
# inactive slots and prompt padding land there with pos = -1, so masking
# stays exact without branching.  A sliding-window layer maps positions into
# a logical ring of ceil((window+1)/page) pages — the same physical pages
# are cyclically overwritten, and the stored absolute positions keep the
# attention mask exact (same trick as the dense ring cache above).
#
# Quantized storage mode (Policy.kv_dtype == "int8"): pk/pv hold int8 codes
# and two parallel *scale pools* hold per-entry, per-kv-head fp32 absmax
# scales:
#
#   pk_scale / pv_scale : (P, page, H_kv)
#
# Each written token row (H_kv, D) is quantized independently —
# q = round(x / s), s = absmax(x)/127 — so scatter writes stay
# read-modify-write-free and a token's stored code depends only on its own
# K/V values.  That per-entry determinism is what keeps shared-prefix
# serving bit-identical to unshared serving on a quantized pool: the same
# token row quantizes to the same bytes no matter which request wrote it.
# Scales travel with their pages through COW copies, trie mappings and
# eviction exactly like pk/pv.

PAGED_KEYS = ("pk", "pv", "ppos", "pk_scale", "pv_scale")
PAGED_DATA_KEYS = ("pk", "pv", "pk_scale", "pv_scale")

INT8_QMAX = 127.0


def quantize_kv(x):
    """Quantize K/V rows to int8 with per-entry, per-head absmax scales.

    x: (..., H, D) float -> (int8 codes (..., H, D), fp32 scales (..., H)).
    All-zero rows get scale 0 (codes 0 -> dequantize to exact 0).
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = amax / INT8_QMAX
    q = jnp.round(xf / jnp.maximum(scale, 1e-30)[..., None])
    q = jnp.clip(q, -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return q, scale


def dequantize_kv(q, scale, dtype=jnp.float32):
    """Inverse of :func:`quantize_kv` (up to rounding)."""
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)) \
        .astype(dtype)


def _scatter_kv(cache: dict, out: dict, val, phys, off) -> None:
    """Scatter new K/V rows into ``out``'s pools at (phys, off) —
    quantizing (codes + scale rows) when the pool is int8, casting to
    the pool dtype otherwise.  ``val``: {"k"/"v": (..., H, D)} rows
    aligned with phys/off.  Shared by the prefill-chunk and
    decode-single-token writes so the two paths can never desynchronize
    their quantized layout."""
    quant = "pk_scale" in cache
    for key, pool_key in (("k", "pk"), ("v", "pv")):
        if quant:
            q, sc = quantize_kv(val[key])
            out[pool_key] = cache[pool_key].at[phys, off].set(q)
            out[pool_key + "_scale"] = \
                cache[pool_key + "_scale"].at[phys, off].set(sc)
        else:
            out[pool_key] = cache[pool_key].at[phys, off].set(
                val[key].astype(cache[pool_key].dtype))


def paged_pool_bytes(cache: dict) -> int:
    """Total bytes of every paged-pool leaf (K/V pages + scale pools +
    positions) across a full model cache — the number pool sizing and
    the serving metrics report."""
    total = 0
    for stack_c in cache["layers"]:
        for c in stack_c:
            if isinstance(c, dict):
                for key in PAGED_KEYS:
                    if key in c:
                        a = c[key]
                        total += a.size * a.dtype.itemsize
    return total


def paged_layer_cache_shape(cfg: ModelConfig, spec: LayerSpec,
                            num_pages: int, page_size: int, max_slots: int,
                            max_len: int, dtype,
                            kv_dtype: str = "auto") -> dict:
    """Paged cache for one layer.  ATTN / HYBRID attention K/V become page
    pools; MLA and recurrent families keep their dense per-slot state (the
    slot API — admit/retire — is identical for them).

    kv_dtype selects pool storage: "auto" stores at ``dtype``; "bf16" /
    "fp16" override the pool dtype; "int8" stores quantized codes plus
    per-entry scale pools — for pure attention layers only.  Hybrid
    layers keep full-precision pools (their SSM/conv state is dense fp32
    anyway), the same families that opt out of prefix sharing.
    """
    from repro.core.precision import kv_store_dtype
    hd = cfg.resolved_head_dim
    quant = kv_dtype == "int8" and spec.mixer == ATTN
    pool_dtype = (jnp.int8 if quant
                  else kv_store_dtype(kv_dtype, dtype, allow_int8=False))

    def pool():
        P = num_pages + 1                               # +1 dump page
        out = {"pk": jnp.zeros((P, page_size, cfg.num_kv_heads, hd),
                               pool_dtype),
               "pv": jnp.zeros((P, page_size, cfg.num_kv_heads, hd),
                               pool_dtype),
               "ppos": jnp.full((P, page_size), -1, jnp.int32)}
        if quant:
            out["pk_scale"] = jnp.zeros(
                (P, page_size, cfg.num_kv_heads), jnp.float32)
            out["pv_scale"] = jnp.zeros(
                (P, page_size, cfg.num_kv_heads), jnp.float32)
        return out

    if spec.mixer == ATTN:
        return pool()
    if spec.mixer == HYBRID:
        out = pool()
        dense = layer_cache_shape(cfg, spec, max_slots, max_len, dtype)
        out["ssm"] = dense["ssm"]
        out["conv"] = dense["conv"]
        return out
    # MLA / mLSTM / sLSTM: dense per-slot state behind the same slot API
    return layer_cache_shape(cfg, spec, max_slots, max_len, dtype)


def paged_ring_len(window: Optional[int], page_size: int,
                   pages_per_slot: int) -> int:
    """Logical ring length (multiple of page_size) a layer writes into.
    Full attention uses the whole per-slot page range; windowed layers
    cycle through ceil((window+1)/page) logical pages."""
    if window is None:
        return pages_per_slot * page_size
    pages_w = -(-(window + 1) // page_size)
    return min(pages_w, pages_per_slot) * page_size


def paged_write_prefill(cache: dict, new: dict, cache_pos, block_tables, *,
                        ring_len: int) -> dict:
    """Scatter a prompt's K/V into pool pages via the slot block tables.

    cache_pos: (B, S) absolute positions (-1 = padding); block_tables:
    (B, pages_per_slot) physical page ids (-1 = unallocated).  Only the
    last min(S, ring_len) valid tokens per row are written (ring layers
    would otherwise scatter twice into one entry, which is unordered).
    """
    out = dict(cache)
    page = cache["ppos"].shape[1]
    dump = cache["ppos"].shape[0] - 1
    B, S = cache_pos.shape
    take = min(S, ring_len)
    valid = jnp.maximum(cache_pos.max(axis=1) + 1, 0)          # (B,)
    start = jnp.clip(valid - take, 0, S - take)
    idx = start[:, None] + jnp.arange(take)[None, :]           # (B, take)
    b_idx = jnp.arange(B)[:, None]
    pos_w = cache_pos[b_idx, idx]                              # (B, take)
    rp = jnp.where(pos_w >= 0, pos_w % ring_len, 0)
    lp, off = rp // page, rp % page
    phys = jnp.take_along_axis(block_tables, lp, axis=1)       # (B, take)
    ok = (pos_w >= 0) & (phys >= 0)
    phys = jnp.where(ok, phys, dump)
    _scatter_kv(cache, out,
                {key: new[key][b_idx, idx] for key in ("k", "v")},
                phys, off)                                     # (B,take,H,D)
    out["ppos"] = cache["ppos"].at[phys, off].set(
        jnp.where(ok, pos_w, -1))
    return out


def paged_write_decode(cache: dict, new: dict, lengths, block_tables,
                       active=None, *, ring_len: int) -> dict:
    """Write one token per slot at absolute position ``lengths`` (B,).
    Inactive slots (active == False) are routed to the dump page."""
    out = dict(cache)
    page = cache["ppos"].shape[1]
    dump = cache["ppos"].shape[0] - 1
    B = lengths.shape[0]
    rp = lengths % ring_len
    lp, off = rp // page, rp % page
    phys = block_tables[jnp.arange(B), lp]
    ok = phys >= 0
    if active is not None:
        ok &= active
    phys = jnp.where(ok, phys, dump)
    _scatter_kv(cache, out, {key: new[key][:, 0] for key in ("k", "v")},
                phys, off)                                     # (B, H, D)
    out["ppos"] = cache["ppos"].at[phys, off].set(
        jnp.where(ok, lengths, -1))
    return out


def paged_write_decode_multi(cache: dict, new: dict, lengths, block_tables,
                             active=None, *, ring_len: int) -> dict:
    """Scatter a speculation window of ``K1`` tokens per slot at absolute
    positions ``lengths[b] .. lengths[b] + K1 - 1`` (the draft-verify
    forward writes the pending token plus every drafted token in one
    pass; rejected entries are rewound afterwards via
    :func:`paged_truncate`).

    new: {"k"/"v": (B, K1, H, D)}; ``active``: (B,) or (B, K1) bool —
    masked entries go to the dump page.  Unlike the single-token decode
    write, positions at or beyond ``ring_len`` are *dumped*, never
    wrapped: a speculative write that wrapped the ring would clobber
    live early-context entries that a rejection could not restore
    (windowed/ring layers therefore must not take this path — the
    engine gates speculation to non-windowed attention).
    """
    out = dict(cache)
    page = cache["ppos"].shape[1]
    dump = cache["ppos"].shape[0] - 1
    B, K1 = new["k"].shape[:2]
    pos = lengths[:, None] + jnp.arange(K1)[None, :]           # (B, K1)
    ok = pos < ring_len
    rp = jnp.where(ok, pos, 0)
    lp, off = rp // page, rp % page
    phys = jnp.take_along_axis(block_tables, lp, axis=1)       # (B, K1)
    ok &= phys >= 0
    if active is not None:
        ok &= active if active.ndim == 2 else active[:, None]
    phys = jnp.where(ok, phys, dump)
    _scatter_kv(cache, out, {key: new[key] for key in ("k", "v")},
                phys, off)                                     # (B,K1,H,D)
    out["ppos"] = cache["ppos"].at[phys, off].set(
        jnp.where(ok, pos, -1))
    return out


def paged_write_packed(cache: dict, new: dict, slot_ids, positions,
                       block_tables, *, ring_len: int) -> dict:
    """Scatter a token-packed stream's K/V: token t of the flat stream
    belongs to slot ``slot_ids[t]`` at absolute position ``positions[t]``
    and lands in that slot's pages via ``block_tables``.

    new: {"k"/"v": (1, T, H, D)}; slot_ids/positions: (T,) with -1 =
    padding lane.  Like the speculative multi-write, positions at or
    beyond ``ring_len`` are *dumped*, never wrapped — the packed path is
    gated to non-windowed attention, so a wrap would only ever clobber
    live context.  Padding lanes, unallocated pages and out-of-range
    positions all route to the dump page.
    """
    out = dict(cache)
    page = cache["ppos"].shape[1]
    dump = cache["ppos"].shape[0] - 1
    B = block_tables.shape[0]
    ok = (slot_ids >= 0) & (positions >= 0) & (positions < ring_len)
    rp = jnp.where(ok, positions, 0)
    lp, off = rp // page, rp % page
    safe_slot = jnp.clip(slot_ids, 0, B - 1)
    phys = block_tables[safe_slot, lp]                          # (T,)
    ok &= phys >= 0
    phys = jnp.where(ok, phys, dump)
    _scatter_kv(cache, out, {key: new[key][0] for key in ("k", "v")},
                phys, off)                                      # (T, H, D)
    out["ppos"] = cache["ppos"].at[phys, off].set(
        jnp.where(ok, positions, -1))
    return out


def paged_truncate(cache, block_tables, keep_len) -> dict:
    """Rewind speculative writes: mark every entry of the slots' pages
    whose absolute position is >= ``keep_len[b]`` empty (pos = -1).

    Stale K/V codes (and int8 scale rows) may remain in the page pools —
    they are unreachable once their positions are -1, exactly like the
    stale data :func:`reset_pages` leaves behind — so only ``ppos``
    needs rewriting.  Safe under sharing: a shared prefix page only
    holds positions < matched_len <= keep_len, so its write-back is a
    no-op even when several slots scatter it in one call, and dump-page
    rows (block table -1) are always -1 already.
    """
    if "ppos" not in cache:
        return cache
    out = dict(cache)
    # pool dim is second-to-last: ppos is (P, page) or (R, P, page)
    dump = cache["ppos"].shape[-2] - 1
    safe = jnp.where(block_tables >= 0, block_tables, dump)    # (B, npages)
    if cache["ppos"].ndim == 3:          # leading scan-repeats dim
        pos = cache["ppos"][:, safe]                 # (R, B, npages, page)
        keep = pos < keep_len[None, :, None, None]
        out["ppos"] = cache["ppos"].at[:, safe].set(
            jnp.where(keep, pos, -1))
    else:
        pos = cache["ppos"][safe]                    # (B, npages, page)
        keep = pos < keep_len[:, None, None]
        out["ppos"] = cache["ppos"].at[safe].set(jnp.where(keep, pos, -1))
    return out


def paged_truncate_all(cache: dict, block_tables, keep_len) -> dict:
    """:func:`paged_truncate` over every paged layer of a model cache."""
    return {"layers": tuple(
        tuple(paged_truncate(c, block_tables, keep_len) for c in stack_c)
        for stack_c in cache["layers"])}


def paged_gather(cache: dict, block_tables):
    """Dense per-slot view of the pool: (B, pages*page, H, D) k/v plus
    (B, pages*page) positions.  Unallocated table entries read the dump
    page and are masked to pos = -1.  Quantized pools are dequantized on
    gather (fp32 out; callers cast to their compute dtype)."""
    dump = cache["ppos"].shape[0] - 1
    safe = jnp.where(block_tables >= 0, block_tables, dump)
    k = cache["pk"][safe]                      # (B, pages, page, H, D)
    v = cache["pv"][safe]
    if "pk_scale" in cache:
        k = dequantize_kv(k, cache["pk_scale"][safe])
        v = dequantize_kv(v, cache["pv_scale"][safe])
    kp = jnp.where((block_tables >= 0)[..., None],
                   cache["ppos"][safe], -1)    # (B, pages, page)
    B, npg, page = kp.shape
    return (k.reshape(B, npg * page, *k.shape[3:]),
            v.reshape(B, npg * page, *v.shape[3:]),
            kp.reshape(B, npg * page))


def copy_pages(cache, src, dst, keep_below) -> dict:
    """Copy-on-write clone: page ``dst[i]`` becomes a copy of ``src[i]``
    with only the entries at absolute positions ``0 <= pos <
    keep_below[i]`` kept valid (the rest are masked to pos = -1).

    This is how a request whose prefix match ends *inside* a page gets a
    private tail page: the shared source page is read, never written,
    and the writer's suffix prefill / decode lands in the copy.  Rows
    may be padded with src = dst = dump, keep_below = 0 (the dump page's
    positions are forced to -1, which is their invariant anyway).
    """
    if "ppos" not in cache:
        return cache
    out = dict(cache)
    data_keys = [k for k in PAGED_DATA_KEYS if k in cache]
    if cache["ppos"].ndim == 3:          # leading scan-repeats dim
        pos = cache["ppos"][:, src]                      # (R, N, page)
        keep = (pos >= 0) & (pos < keep_below[None, :, None])
        out["ppos"] = cache["ppos"].at[:, dst].set(
            jnp.where(keep, pos, -1))
        for k in data_keys:
            out[k] = cache[k].at[:, dst].set(cache[k][:, src])
    else:
        pos = cache["ppos"][src]                         # (N, page)
        keep = (pos >= 0) & (pos < keep_below[:, None])
        out["ppos"] = cache["ppos"].at[dst].set(jnp.where(keep, pos, -1))
        for k in data_keys:
            out[k] = cache[k].at[dst].set(cache[k][src])
    return out


def copy_pages_all(cache: dict, src, dst, keep_below) -> dict:
    """:func:`copy_pages` over every paged layer of a full model cache."""
    return {"layers": tuple(
        tuple(copy_pages(c, src, dst, keep_below) for c in stack_c)
        for stack_c in cache["layers"])}


def reset_pages_all(cache: dict, pages) -> dict:
    """:func:`reset_pages` over every layer of a full model cache."""
    return {"layers": tuple(tuple(reset_pages(c, pages) for c in stack_c)
                            for stack_c in cache["layers"])}


def reset_pages(cache, pages) -> dict:
    """Mark freshly (re)allocated physical pages empty (``pages`` may be
    padded with the dump page id, whose pos is always -1 anyway).  Only
    ``ppos`` needs clearing: stale K/V from a page's previous owner is
    unreachable once its positions are -1."""
    if "ppos" not in cache:
        return cache
    out = dict(cache)
    # pool leaves may carry a leading scan-repeats dim
    if cache["ppos"].ndim == 3:
        out["ppos"] = cache["ppos"].at[:, pages, :].set(-1)
    else:
        out["ppos"] = cache["ppos"].at[pages, :].set(-1)
    return out


# -- host-memory offload / restore: the overload escape valve ---------------
#
# Under pool pressure the scheduler preempts a slot: its pages' bytes move
# to host memory (``offload_pages``) so the device pages can be freed, and
# move back verbatim (``restore_pages``) when the request is re-admitted —
# decode then resumes bit-identically, no recompute.  The same primitives
# back the prefix cache's host spill tier.  Both run outside jit (rare
# events on the slow path); ordering is safe because the engine always
# threads the *latest* cache pytree through them.


def offload_pages(cache: dict, pages) -> list:
    """Snapshot the full contents of physical ``pages`` to host memory.

    Returns a nested blob ``[per stack][per layer]`` where paged layers
    contribute ``{leaf key: np.ndarray}`` covering every paged leaf
    (K/V codes, int8 scale pools, positions — ``PAGED_KEYS``) and
    non-paged layers (dense per-slot state) contribute ``None``.  The
    gather device-syncs; leaves with a leading scan-repeats dim keep it.
    """
    import numpy as np
    pages = np.asarray(pages, np.int32)
    blob = []
    for stack_c in cache["layers"]:
        row = []
        for c in stack_c:
            if not (isinstance(c, dict) and "ppos" in c):
                row.append(None)
                continue
            rep = c["ppos"].ndim == 3          # leading scan-repeats dim
            row.append({k: np.asarray(c[k][:, pages] if rep
                                      else c[k][pages])
                        for k in PAGED_KEYS if k in c})
        blob.append(row)
    return blob


def restore_pages(cache: dict, blob: list, pages) -> dict:
    """Scatter an :func:`offload_pages` blob back into physical ``pages``
    (any pages — restore need not land where the snapshot was taken).
    Every paged leaf row is overwritten wholesale, so no prior
    ``reset_pages`` is needed: stale previous-owner state cannot survive.
    """
    import numpy as np
    pages = np.asarray(pages, np.int32)
    layers = []
    for stack_c, brow in zip(cache["layers"], blob):
        row = []
        for c, b in zip(stack_c, brow):
            if b is None:
                row.append(c)
                continue
            rep = c["ppos"].ndim == 3
            row.append({k: (c[k].at[:, pages].set(b[k]) if rep
                            else c[k].at[pages].set(b[k]))
                        if k in b else c[k] for k in c})
        layers.append(tuple(row))
    return {"layers": tuple(layers)}


def blob_bytes(blob: list) -> int:
    """Host bytes an :func:`offload_pages` blob occupies (what the
    byte-budgeted host tier accounts against its capacity)."""
    return sum(a.nbytes for row in blob for d in row if d
               for a in d.values())


# -- slot view / merge: admission prefill on a slot subset ------------------


def slot_view(cache: dict, n_view: int) -> dict:
    """A fresh ``n_view``-slot working view of a persistent multi-slot
    cache: paged pool leaves pass through (they are shared, indexed via
    block tables), per-slot leaves come back *empty* (zeros, pos = -1) —
    an admitted request always starts from clean slot state."""

    def fresh(key, a):
        shape = (a.shape[0], n_view) + a.shape[2:]      # [repeats, slots,...]
        if key == "pos":
            return jnp.full(shape, -1, a.dtype)
        return jnp.zeros(shape, a.dtype)

    def layer(c):
        return {k: (v if k in PAGED_KEYS else fresh(k, v))
                for k, v in c.items()}

    return {"layers": tuple(tuple(layer(c) for c in stack_c)
                            for stack_c in cache["layers"])}


def slot_merge(cache: dict, view: dict, slots) -> dict:
    """Scatter a slot view produced by :func:`slot_view` (and updated by a
    prefill) back into the persistent cache at ``slots`` (n_view,)."""

    def layer(c, vv):
        return {k: (vv[k] if k in PAGED_KEYS
                    else c[k].at[:, slots].set(vv[k].astype(c[k].dtype)))
                for k in c}

    return {"layers": tuple(
        tuple(layer(c, vv) for c, vv in zip(sc, sv))
        for sc, sv in zip(cache["layers"], view["layers"]))}
