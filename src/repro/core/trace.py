"""Structured serve-loop tracing: event timeline, lifecycle spans, exporters.

``ServeTracer`` is a low-overhead, ring-buffered event recorder threaded through
``serve_continuous`` / ``ContinuousScheduler`` / ``RadixPrefixCache`` /
``HostKVStore`` behind a ``trace=None`` argument.  Every emit site is guarded
(``if trace is not None``) so the untraced path costs nothing; the traced path
appends one plain dict per event to a bounded deque.

Three record families share one flat schema (see ``EVENT_SCHEMAS``):

* **iteration** — one record per serve-loop iteration: token budget used vs.
  ``max_batched_tokens``, decode lanes vs. chunk segments, the chosen packed
  width bucket and padded lanes, the host/device wall split for the iteration,
  and gauges (pages in use, host-tier bytes, radix-trie nodes) sampled each step.
* **request lifecycle** — ``enqueue → admit → prefill_chunk* → first_token →
  (preempt/offload/restore)* → retire``, the retire stamped with the request's
  structured ``RequestOutcome``.
* **scheduler decisions** — ``admission_denied`` (with reason), ``preempt``
  (victim choice), ``prefix_hit`` / ``prefix_evict`` (incl. host spills),
  ``host_evict`` / ``host_refused`` (host-tier pressure), ``cancel`` (deadline
  or queue-wait rejection).

Time: all ``t`` values are seconds relative to the tracer origin (set by the
engine at serve start).  The clock is injectable (``clock=`` callable) so tests
can drive a fake monotonic clock and obtain byte-identical JSONL across runs.

Exporters:

* ``to_jsonl`` — one event per line; the first line is a ``trace_header``
  carrying the schema version and drop counter.
* ``to_perfetto`` — Chrome trace-event JSON (``{"traceEvents": [...]}``),
  loadable at https://ui.perfetto.dev: one track for the scheduler (iteration
  slices + decision instants), one for device dispatches (named spans), one for
  the host KV tier, and one per slot (request-occupancy slices admit→retire).

Validation: ``validate_event`` / ``validate_events`` / ``validate_jsonl`` check
every event against ``EVENT_SCHEMAS``; ``python -m repro.core.trace validate
PATH`` runs the same check from the command line (used by CI on emitted traces).
"""

from __future__ import annotations

import json
import time
from collections import deque

TRACE_SCHEMA_VERSION = 1

# Sentinel for schema fields that may be absent (or null) on an event.
_OPTIONAL = True
_REQUIRED = False

_NUM = ("num",)      # int or float, bools rejected
_INT = ("int",)      # int only, bools rejected
_STR = ("str",)
_BOOL = ("bool",)

# kind -> field -> (type tag, optional?).  Common fields "kind" and "t" are
# checked for every event; "t" is seconds since trace origin.
EVENT_SCHEMAS = {
    # --- iteration records -------------------------------------------------
    "iteration": {
        "iter": (_INT, _REQUIRED),          # serve-loop iteration index
        "dur": (_NUM, _REQUIRED),           # iteration wall seconds
        "host_s": (_NUM, _REQUIRED),        # dur minus device dispatch time
        "device_s": (_NUM, _REQUIRED),      # sum of device spans this iteration
        "budget": (_INT, _REQUIRED),        # max_batched_tokens (0 = unbudgeted)
        "budget_used": (_INT, _REQUIRED),   # tokens dispatched this iteration
        "decode_lanes": (_INT, _REQUIRED),
        "chunk_segments": (_INT, _REQUIRED),
        "chunk_tokens": (_INT, _REQUIRED),  # real (unpadded) prefill tokens
        "width_bucket": (_INT, _REQUIRED),  # chosen packed/chunk width (0 = n/a)
        "padded_lanes": (_INT, _REQUIRED),  # padding tokens inside the bucket
        "idle": (_BOOL, _REQUIRED),         # no work dispatched this iteration
        "pages_in_use": (_INT, _REQUIRED),  # KV page-pool gauge
        "host_bytes": (_INT, _REQUIRED),    # host KV tier gauge
        "trie_nodes": (_INT, _REQUIRED),    # radix prefix-trie gauge
    },
    "span": {
        "name": (_STR, _REQUIRED),          # e.g. decode, packed, chunk, verify
        "dur": (_NUM, _REQUIRED),
        "track": (_STR, _REQUIRED),         # "device"
    },
    # --- request lifecycle -------------------------------------------------
    "enqueue": {
        "uid": (_INT, _REQUIRED),
        "prompt_len": (_INT, _REQUIRED),
        "max_new": (_INT, _REQUIRED),
        "deadline": (_NUM, _OPTIONAL),      # absolute serve-relative seconds
    },
    "admit": {
        "uid": (_INT, _REQUIRED),
        "slot": (_INT, _REQUIRED),
        "matched_tokens": (_INT, _REQUIRED),  # prefix-cache reuse at admit
        "pages": (_INT, _REQUIRED),
        "resume": (_STR, _REQUIRED),        # "no" | "hostkv" | "recompute"
    },
    "prefill_chunk": {
        "uid": (_INT, _REQUIRED),
        "slot": (_INT, _REQUIRED),
        "start": (_INT, _REQUIRED),         # chunk start position in the prompt
        "len": (_INT, _REQUIRED),
    },
    "first_token": {
        "uid": (_INT, _REQUIRED),
        "ttft_s": (_NUM, _REQUIRED),
    },
    "retire": {
        "uid": (_INT, _REQUIRED),
        "slot": (_INT, _REQUIRED),
        "status": (_STR, _REQUIRED),        # RequestOutcome.status
        "preemptions": (_INT, _REQUIRED),
        "deadline_missed": (_BOOL, _REQUIRED),
        "latency_s": (_NUM, _REQUIRED),
        "generated": (_INT, _REQUIRED),
    },
    "preempt": {
        "uid": (_INT, _REQUIRED),
        "slot": (_INT, _REQUIRED),
        "policy": (_STR, _REQUIRED),        # victim-choice policy (lru, ...)
        "n_pages": (_INT, _REQUIRED),
        "offloaded": (_BOOL, _REQUIRED),    # pages went to the host tier
    },
    "offload": {
        "uid": (_INT, _REQUIRED),
        "slot": (_INT, _REQUIRED),
        "n_pages": (_INT, _REQUIRED),
    },
    "restore": {
        "uid": (_INT, _REQUIRED),
        "slot": (_INT, _REQUIRED),
        "mode": (_STR, _REQUIRED),          # "hostkv" | "recompute"
        "n_pages": (_INT, _REQUIRED),
    },
    # --- scheduler decisions ----------------------------------------------
    "admission_denied": {
        "uid": (_INT, _REQUIRED),
        "reason": (_STR, _REQUIRED),        # no_free_slot | pool_exhausted | ...
        "pages_needed": (_INT, _OPTIONAL),
    },
    "cancel": {
        "uid": (_INT, _REQUIRED),
        "status": (_STR, _REQUIRED),        # timed_out | rejected
        "detail": (_STR, _REQUIRED),
    },
    "prefix_hit": {
        "uid": (_INT, _REQUIRED),
        "matched_tokens": (_INT, _REQUIRED),
        "pages_shared": (_INT, _REQUIRED),
    },
    "prefix_evict": {
        "requested": (_INT, _REQUIRED),     # pages the allocator asked for
        "freed": (_INT, _REQUIRED),
        "spilled": (_INT, _REQUIRED),       # pages copied to the host tier
    },
    "host_evict": {
        "bytes": (_INT, _REQUIRED),         # victim blob size
    },
    "host_refused": {
        "bytes": (_INT, _REQUIRED),         # rejected put size
    },
    # --- weight compression (emitted once, at serve start) -----------------
    "weights": {
        "dtype": (_STR, _REQUIRED),         # policy weights_dtype
        "weight_bytes": (_INT, _REQUIRED),  # serve-path matmul weight bytes
        "weight_bytes_dense": (_INT, _REQUIRED),   # same set, uncompressed
        "quantized_tensors": (_INT, _REQUIRED),
    },
}


def _type_ok(tag, v):
    if tag == "num":
        return isinstance(v, (int, float)) and not isinstance(v, bool)
    if tag == "int":
        return isinstance(v, int) and not isinstance(v, bool)
    if tag == "str":
        return isinstance(v, str)
    if tag == "bool":
        return isinstance(v, bool)
    raise ValueError(f"unknown type tag {tag!r}")


def validate_event(ev):
    """Return a list of error strings for one event dict (empty = valid)."""
    errs = []
    if not isinstance(ev, dict):
        return [f"event is not a dict: {type(ev).__name__}"]
    kind = ev.get("kind")
    if kind == "trace_header":
        if ev.get("v") != TRACE_SCHEMA_VERSION:
            errs.append(f"trace_header: bad schema version {ev.get('v')!r}")
        return errs
    schema = EVENT_SCHEMAS.get(kind)
    if schema is None:
        return [f"unknown event kind {kind!r}"]
    t = ev.get("t")
    if not (isinstance(t, (int, float)) and not isinstance(t, bool)):
        errs.append(f"{kind}: field 't' must be numeric, got {t!r}")
    for field, (tag, optional) in schema.items():
        if field not in ev or ev[field] is None:
            if not optional:
                errs.append(f"{kind}: missing required field {field!r}")
            continue
        if not _type_ok(tag[0], ev[field]):
            errs.append(
                f"{kind}: field {field!r} expected {tag[0]}, "
                f"got {ev[field]!r}"
            )
    extra = set(ev) - set(schema) - {"kind", "t"}
    if extra:
        errs.append(f"{kind}: unknown fields {sorted(extra)}")
    return errs


def validate_events(events):
    """Validate an iterable of event dicts; return all error strings."""
    errs = []
    for i, ev in enumerate(events):
        for e in validate_event(ev):
            errs.append(f"event {i}: {e}")
    return errs


def validate_jsonl(path):
    """Validate a JSONL trace file. Returns (num_events, errors)."""
    errs = []
    n = 0
    with open(path) as f:
        first = f.readline()
        if not first:
            return 0, ["empty trace file"]
        try:
            header = json.loads(first)
        except json.JSONDecodeError as e:
            return 0, [f"line 1: invalid JSON: {e}"]
        if header.get("kind") != "trace_header":
            errs.append("line 1: first line must be a trace_header")
        else:
            errs.extend(validate_event(header))
        for lineno, line in enumerate(f, start=2):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                errs.append(f"line {lineno}: invalid JSON: {e}")
                continue
            n += 1
            for e in validate_event(ev):
                errs.append(f"line {lineno}: {e}")
    return n, errs


def _json_default(o):
    # numpy scalars sneak into emit sites despite int()/float() discipline;
    # coerce them so exports never crash on a forgotten cast.
    try:
        import numpy as np

        if isinstance(o, np.integer):
            return int(o)
        if isinstance(o, np.floating):
            return float(o)
        if isinstance(o, np.bool_):
            return bool(o)
    except ImportError:
        pass
    raise TypeError(f"not JSON serializable: {type(o).__name__}")


class ServeTracer:
    """Ring-buffered structured event recorder for the serve loop.

    Parameters
    ----------
    clock:
        Monotonic ``() -> float`` used for every timestamp the engine takes
        while this tracer is attached.  Defaults to ``time.perf_counter``.
        Injecting a deterministic fake makes traces byte-reproducible.
    ring_size:
        Maximum buffered events; older events are dropped (and counted in
        ``dropped``) once the ring is full.
    """

    def __init__(self, clock=None, ring_size=1_000_000):
        self.clock = clock if clock is not None else time.perf_counter
        self.ring_size = int(ring_size)
        self.events = deque(maxlen=self.ring_size)
        self.dropped = 0
        self._origin = 0.0

    def set_origin(self, t):
        """Anchor t=0 at absolute clock value ``t`` (serve start)."""
        self._origin = float(t)

    def now(self):
        """Seconds since the trace origin, from the injected clock."""
        return self.clock() - self._origin

    def emit(self, kind, t, **fields):
        """Record one event at serve-relative time ``t``."""
        if len(self.events) == self.ring_size:
            self.dropped += 1
        ev = {"kind": kind, "t": float(t)}
        ev.update(fields)
        self.events.append(ev)

    def emit_now(self, kind, **fields):
        self.emit(kind, self.now(), **fields)

    def iter_events(self, kind=None):
        if kind is None:
            return iter(self.events)
        return (e for e in self.events if e["kind"] == kind)

    def reset(self):
        self.events.clear()
        self.dropped = 0
        self._origin = 0.0

    # --- exporters ---------------------------------------------------------

    def header(self):
        return {
            "kind": "trace_header",
            "v": TRACE_SCHEMA_VERSION,
            "events": len(self.events),
            "dropped": self.dropped,
        }

    def to_jsonl(self, out):
        """Write the trace as JSONL to a path or file-like object.

        The first line is a ``trace_header``; every following line is one
        event.  Keys are sorted and separators fixed so that identical event
        streams produce byte-identical files.
        """
        close = False
        if isinstance(out, str):
            f = open(out, "w")
            close = True
        else:
            f = out
        try:
            dump = lambda o: json.dumps(
                o, sort_keys=True, separators=(",", ":"), default=_json_default
            )
            f.write(dump(self.header()) + "\n")
            for ev in self.events:
                f.write(dump(ev) + "\n")
        finally:
            if close:
                f.close()

    def to_perfetto(self, out):
        """Write a Chrome trace-event JSON file loadable in Perfetto."""
        doc = to_perfetto_dict(list(self.events), dropped=self.dropped)
        close = False
        if isinstance(out, str):
            f = open(out, "w")
            close = True
        else:
            f = out
        try:
            json.dump(doc, f, default=_json_default)
        finally:
            if close:
                f.close()


# Perfetto track layout (all under one pid).
_PID = 1
_TID_SCHED = 1
_TID_DEVICE = 2
_TID_HOST = 3
_TID_SLOT0 = 10  # slot s renders on tid 10 + s


def _us(t):
    return round(float(t) * 1e6, 3)


def to_perfetto_dict(events, dropped=0):
    """Convert a list of event dicts into Chrome trace-event JSON.

    Tracks: scheduler (iteration slices + decision instants), device (named
    dispatch spans), host KV tier, and one per slot holding a ``req <uid>``
    slice from admit to retire (or preempt).  Gauges become counter tracks.
    """
    te = []

    def meta(tid, name):
        te.append(
            {
                "ph": "M",
                "pid": _PID,
                "tid": tid,
                "name": "thread_name",
                "args": {"name": name},
            }
        )

    te.append(
        {
            "ph": "M",
            "pid": _PID,
            "name": "process_name",
            "args": {"name": "repro-serve"},
        }
    )
    meta(_TID_SCHED, "scheduler")
    meta(_TID_DEVICE, "device")
    meta(_TID_HOST, "host-kv")

    def slice_(tid, name, t, dur, args=None):
        ev = {
            "ph": "X",
            "pid": _PID,
            "tid": tid,
            "name": name,
            "ts": _us(t),
            "dur": max(_us(dur), 0.001),
            "cat": "serve",
        }
        if args:
            ev["args"] = args
        te.append(ev)

    def instant(tid, name, t, args=None):
        ev = {
            "ph": "i",
            "s": "t",
            "pid": _PID,
            "tid": tid,
            "name": name,
            "ts": _us(t),
            "cat": "serve",
        }
        if args:
            ev["args"] = args
        te.append(ev)

    def counter(name, t, value):
        te.append(
            {
                "ph": "C",
                "pid": _PID,
                "name": name,
                "ts": _us(t),
                "args": {"value": value},
            }
        )

    seen_slots = set()
    open_slot = {}  # slot -> (uid, since there is at most one open req/slot)
    t_end = 0.0

    for ev in events:
        k = ev["kind"]
        t = ev["t"]
        t_end = max(t_end, t + float(ev.get("dur", 0.0)))
        if k == "iteration":
            args = {
                f: ev[f]
                for f in (
                    "iter",
                    "budget",
                    "budget_used",
                    "decode_lanes",
                    "chunk_segments",
                    "chunk_tokens",
                    "width_bucket",
                    "padded_lanes",
                    "idle",
                    "host_s",
                    "device_s",
                )
                if f in ev
            }
            name = "idle" if ev.get("idle") else "iteration"
            slice_(_TID_SCHED, name, t, ev["dur"], args)
            counter("pages_in_use", t, ev.get("pages_in_use", 0))
            counter("host_bytes", t, ev.get("host_bytes", 0))
            counter("trie_nodes", t, ev.get("trie_nodes", 0))
        elif k == "span":
            slice_(_TID_DEVICE, ev["name"], t, ev["dur"])
        elif k == "admit":
            slot = ev["slot"]
            tid = _TID_SLOT0 + slot
            if slot not in seen_slots:
                seen_slots.add(slot)
                meta(tid, f"slot {slot}")
            # A lost retire/preempt would leave the previous slice open and
            # corrupt nesting; close it defensively at this admit.
            if slot in open_slot:
                te.append({"ph": "E", "pid": _PID, "tid": tid, "ts": _us(t)})
            te.append(
                {
                    "ph": "B",
                    "pid": _PID,
                    "tid": tid,
                    "name": f"req {ev['uid']}",
                    "ts": _us(t),
                    "cat": "serve",
                    "args": {
                        "uid": ev["uid"],
                        "matched_tokens": ev.get("matched_tokens", 0),
                        "resume": ev.get("resume", "no"),
                    },
                }
            )
            open_slot[slot] = ev["uid"]
        elif k in ("retire", "preempt"):
            slot = ev["slot"]
            tid = _TID_SLOT0 + slot
            if slot in open_slot:
                args = {f: ev[f] for f in ev if f not in ("kind", "t")}
                te.append(
                    {
                        "ph": "E",
                        "pid": _PID,
                        "tid": tid,
                        "ts": _us(t),
                        "args": args,
                    }
                )
                del open_slot[slot]
            if k == "preempt":
                instant(
                    _TID_SCHED,
                    f"preempt uid={ev['uid']}",
                    t,
                    {f: ev[f] for f in ("policy", "n_pages", "offloaded")},
                )
        elif k in ("prefill_chunk", "first_token"):
            slot = ev.get("slot")
            tid = _TID_SLOT0 + slot if slot is not None else _TID_SCHED
            if slot is not None and slot not in seen_slots:
                seen_slots.add(slot)
                meta(tid, f"slot {slot}")
            args = {f: ev[f] for f in ev if f not in ("kind", "t")}
            instant(tid, k, t, args)
        elif k == "weights":
            counter("weight_bytes", t, ev.get("weight_bytes", 0))
            args = {f: ev[f] for f in ev if f not in ("kind", "t")}
            instant(_TID_SCHED, k, t, args)
        elif k in ("offload", "restore", "host_evict", "host_refused"):
            args = {f: ev[f] for f in ev if f not in ("kind", "t")}
            instant(_TID_HOST, k, t, args)
        else:  # enqueue / admission_denied / cancel / prefix_* / unknown
            args = {f: ev[f] for f in ev if f not in ("kind", "t")}
            instant(_TID_SCHED, k, t, args)

    # Close any request slices still open at trace end (e.g. in-flight at stop).
    for slot in sorted(open_slot):
        te.append(
            {
                "ph": "E",
                "pid": _PID,
                "tid": _TID_SLOT0 + slot,
                "ts": _us(t_end),
            }
        )

    return {
        "traceEvents": te,
        "displayTimeUnit": "ms",
        "otherData": {
            "schema_version": TRACE_SCHEMA_VERSION,
            "dropped_events": dropped,
        },
    }


def export(tracer, out_path, fmt="jsonl"):
    """Export ``tracer`` to ``out_path`` in ``fmt`` (jsonl|perfetto|both).

    For ``both``, ``out_path`` names the JSONL file and the Perfetto file is
    written next to it with a ``.perfetto.json`` suffix.  Returns the list of
    written paths.
    """
    if fmt == "jsonl":
        tracer.to_jsonl(out_path)
        return [out_path]
    if fmt == "perfetto":
        tracer.to_perfetto(out_path)
        return [out_path]
    if fmt == "both":
        base = out_path[: -len(".jsonl")] if out_path.endswith(".jsonl") else out_path
        jp, pp = base + ".jsonl", base + ".perfetto.json"
        tracer.to_jsonl(jp)
        tracer.to_perfetto(pp)
        return [jp, pp]
    raise ValueError(f"unknown trace format {fmt!r}")


def _main(argv=None):
    import argparse
    import sys

    ap = argparse.ArgumentParser(
        prog="python -m repro.core.trace",
        description="Validate or summarize a serve-loop JSONL trace.",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    v = sub.add_parser("validate", help="schema-validate a JSONL trace")
    v.add_argument("path")
    v.add_argument("--max-errors", type=int, default=20)
    s = sub.add_parser("summary", help="per-kind event counts and span totals")
    s.add_argument("path")
    args = ap.parse_args(argv)

    if args.cmd == "validate":
        n, errs = validate_jsonl(args.path)
        for e in errs[: args.max_errors]:
            print(f"ERROR: {e}", file=sys.stderr)
        if errs:
            print(f"INVALID: {args.path}: {n} events, {len(errs)} errors")
            return 1
        print(f"OK: {args.path}: {n} events, schema v{TRACE_SCHEMA_VERSION}")
        return 0

    counts = {}
    span_s = {}
    host_s = device_s = 0.0
    with open(args.path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            k = ev.get("kind")
            counts[k] = counts.get(k, 0) + 1
            if k == "span":
                span_s[ev["name"]] = span_s.get(ev["name"], 0.0) + ev["dur"]
            elif k == "iteration":
                host_s += ev["host_s"]
                device_s += ev["device_s"]
    for k in sorted(counts):
        print(f"{k:18s} {counts[k]}")
    for name in sorted(span_s):
        print(f"span[{name}] total {span_s[name]:.4f}s")
    print(f"iteration host_s={host_s:.4f}s device_s={device_s:.4f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
