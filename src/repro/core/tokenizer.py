"""Trie-based longest-match tokenizer — the paper's "Faster Tokenizer".

The paper uses PaddleNLP's FasterTokenizer (a linear-time WordPiece, Song
et al. 2020).  This is the same idea: a character trie over a trained
vocabulary, greedy longest-match-first in a single left-to-right pass, no
backtracking.  It also tracks corpus token frequencies — the input to the
paper's embedding-layer pruning (P2).
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, Iterable, List

PAD, UNK, BOS, EOS = 0, 1, 2, 3
SPECIALS = ["<pad>", "<unk>", "<bos>", "<eos>"]


class FastTokenizer:
    """Greedy longest-match trie tokenizer with trained vocab."""

    def __init__(self, vocab: List[str]):
        assert vocab[:4] == SPECIALS, "vocab must start with the specials"
        self.vocab = list(vocab)
        self.token_to_id: Dict[str, int] = {t: i for i, t in enumerate(vocab)}
        self._trie: dict = {}
        for tok, idx in self.token_to_id.items():
            if idx < 4:
                continue
            node = self._trie
            for ch in tok:
                node = node.setdefault(ch, {})
            node["\0"] = idx

    # -- construction -------------------------------------------------------
    @classmethod
    def train(cls, corpus: Iterable[str], vocab_size: int) -> "FastTokenizer":
        """Vocab = specials + all seen chars + most frequent words/subwords."""
        word_freq: Counter = Counter()
        char_set = set()
        for line in corpus:
            for w in line.split():
                word_freq[w] += 1
                char_set.update(w)
            char_set.add(" ")
        chars = sorted(char_set)
        room = max(0, vocab_size - 4 - len(chars))
        words = [w for w, _ in word_freq.most_common(room) if len(w) > 1]
        vocab = SPECIALS + chars + words
        return cls(vocab[:vocab_size] if len(vocab) > vocab_size else vocab)

    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    # -- encode / decode ------------------------------------------------------
    def encode(self, text: str, bos: bool = True, eos: bool = False
               ) -> List[int]:
        ids = [BOS] if bos else []
        i, n = 0, len(text)
        while i < n:
            node, j = self._trie, i
            best, best_end = None, i
            while j < n and text[j] in node:
                node = node[text[j]]
                j += 1
                if "\0" in node:
                    best, best_end = node["\0"], j
            if best is None:
                ids.append(UNK)
                i += 1
            else:
                ids.append(best)
                i = best_end
        if eos:
            ids.append(EOS)
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        out = []
        for i in ids:
            i = int(i)
            if i in (PAD, BOS):
                continue
            if i == EOS:
                break
            out.append(self.vocab[i] if 0 <= i < len(self.vocab) else "<unk>")
        return "".join(out)

    # -- frequency stats for pruning (P2) -----------------------------------
    def count_frequencies(self, corpus: Iterable[str]) -> Counter:
        freq: Counter = Counter({i: 0 for i in range(4)})
        for line in corpus:
            for tid in self.encode(line, bos=False):
                freq[tid] += 1
        return freq
