"""Speculative decoding — drafters for the draft-verify serving loop.

Decode latency on the continuous path is bound by the number of target-
model forwards: one token per slot per forward.  Speculative decoding
converts spare compute into accepted tokens per step: a cheap *drafter*
proposes K continuation tokens per slot, ONE multi-token verify forward
scores all of them against the paged KV pools
(``models.transformer.forward_verify``), and the rejection sampler
(``sampling.speculative_verify``) keeps the longest valid prefix — so the
emitted stream is distributed exactly as non-speculative sampling, and is
bit-identical under greedy decoding.

Two built-in drafters:

  * :class:`NgramDrafter` — prompt-lookup / self-drafting: propose the K
    tokens that followed the most recent earlier occurrence of the
    context's trailing n-gram.  Needs no extra weights; pays off on
    repetitive continuations (shared system prompts, code, quotes).
  * :class:`DraftModelDrafter` — a small draft model (any registry
    config) decoded greedily for K tokens.  The reference implementation
    runs full forwards over the (bucketed, right-padded) context — cheap
    for genuinely small drafters, and exact enough for acceptance-rate
    purposes; the *target* model never sees the drafter's arithmetic, so
    draft quality only ever affects speed, never correctness.

Drafting is host-side (the n-gram scan needs the emitted-token history
the device doesn't keep); the verify forward, acceptance rule and KV
rewind run fused on device (``engine.serve_continuous``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List, Optional, Sequence

import numpy as np

from repro.core.precision import FP32, Policy


@dataclass
class SpecConfig:
    """Speculative-decoding knobs for ``engine.serve_continuous``.

    k: drafted tokens per slot per step (the verify window is k+1 wide).
    drafter: "ngram" (prompt lookup, no weights) or "draft_model".
    max_ngram/min_ngram: longest/shortest trailing n-gram the lookup
    drafter tries to match (longer first = higher precision).
    draft_cfg/draft_params: the draft model (any registry config).  When
    omitted for drafter="draft_model", the target model drafts for
    itself — the degenerate reference setup (acceptance is 100% under
    greedy), useful for smoke tests and parity checks.
    """
    k: int = 4
    drafter: str = "ngram"
    max_ngram: int = 3
    min_ngram: int = 1
    draft_cfg: Any = None
    draft_params: Any = None


class Drafter:
    """Proposes K continuation tokens per slot from its token context."""

    name = "base"

    def __init__(self, k: int):
        self.k = k

    def propose(self, context: Sequence[int]) -> List[int]:
        raise NotImplementedError

    def propose_slots(self, contexts: List[Optional[Sequence[int]]]
                      ) -> np.ndarray:
        """(slots, k) int32 proposals; ``None`` rows (inactive slots)
        draft zeros — the engine masks them out of the verify write."""
        out = np.zeros((len(contexts), self.k), np.int32)
        for i, ctx in enumerate(contexts):
            if ctx:
                out[i] = self.propose(ctx)
        return out


class NgramDrafter(Drafter):
    """Prompt-lookup drafting: match the context's trailing n-gram
    against its own history and propose what followed last time.

    Tries n = max_ngram .. min_ngram (longest match first, most recent
    occurrence first), scanning at most the trailing ``scan_window``
    tokens — host drafting stays O(window) per slot per step instead of
    growing with the generation history (lookups further back have
    marginal hit rates, and drafts only ever affect speed, never
    correctness).  With no match it proposes the last token repeated —
    greedy decoding of small models degenerates into loops often enough
    that this fallback still earns acceptances, and a bad proposal costs
    nothing but the (already-spent) verify slot.
    """

    name = "ngram"

    def __init__(self, k: int, max_ngram: int = 3, min_ngram: int = 1,
                 scan_window: int = 1024):
        super().__init__(k)
        self.max_ngram = max_ngram
        self.min_ngram = max(1, min_ngram)
        self.scan_window = scan_window

    def propose(self, context: Sequence[int]) -> List[int]:
        ctx = list(context[max(0, len(context) - self.scan_window):])
        k, n_ctx = self.k, len(ctx)
        for n in range(min(self.max_ngram, n_ctx - 1), self.min_ngram - 1,
                       -1):
            pat = ctx[-n:]
            for i in range(n_ctx - n - 1, -1, -1):
                if ctx[i:i + n] == pat:
                    cont = ctx[i + n:i + n + k]
                    if cont:
                        return (cont + [cont[-1]] * k)[:k]
        return [ctx[-1]] * k


class DraftModelDrafter(Drafter):
    """Greedy K-token drafting with a small draft model.

    Contexts are right-padded into power-of-two width buckets (bounding
    retraces) and drafted in one batched jitted call: K full forwards of
    the draft model, each extending the buffer by its argmax.  Padding
    beyond a row's length is causally invisible to the positions that
    matter.
    """

    name = "draft_model"

    def __init__(self, cfg, params, k: int, policy: Policy = FP32):
        super().__init__(k)
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self._fns = {}                       # (B, W) -> jitted draft fn

    def _fn(self, B: int, W: int):
        import jax
        import jax.numpy as jnp
        from repro.models import transformer as T
        key = (B, W)
        fn = self._fns.get(key)
        if fn is not None:
            return fn
        cfg, policy, K = self.cfg, self.policy, self.k

        def draft(params, buf, lens):        # buf (B, W+K), lens (B,)
            b_idx = jnp.arange(B)

            def body(j, buf):
                logits, _ = T.forward_train(params, cfg, buf,
                                            policy=policy, remat=False)
                nxt = jnp.argmax(logits[b_idx, lens - 1 + j],
                                 axis=-1).astype(jnp.int32)
                return buf.at[b_idx, lens + j].set(nxt)

            buf = jax.lax.fori_loop(0, K, body, buf)
            pos = lens[:, None] + jnp.arange(K)[None, :]
            return jnp.take_along_axis(buf, pos, axis=1)

        fn = jax.jit(draft)
        self._fns[key] = fn
        return fn

    def propose_slots(self, contexts: List[Optional[Sequence[int]]]
                      ) -> np.ndarray:
        import jax.numpy as jnp
        live = [(i, list(ctx)) for i, ctx in enumerate(contexts) if ctx]
        out = np.zeros((len(contexts), self.k), np.int32)
        if not live:
            return out
        B = 1 << (len(live) - 1).bit_length()          # batch bucket
        W = 1 << (max(len(c) for _, c in live) - 1).bit_length()
        buf = np.zeros((B, W + self.k), np.int32)
        lens = np.ones((B,), np.int32)                 # pad rows: 1 token
        for r, (_, ctx) in enumerate(live):
            buf[r, :len(ctx)] = ctx
            lens[r] = len(ctx)
        drafted = np.asarray(self._fn(B, W)(
            self.params, jnp.asarray(buf), jnp.asarray(lens)))
        for r, (i, _) in enumerate(live):
            out[i] = drafted[r]
        return out

    def propose(self, context: Sequence[int]) -> List[int]:
        return list(self.propose_slots([context])[0])


def get_drafter(spec: SpecConfig, target_cfg=None, target_params=None,
                policy: Policy = FP32) -> Drafter:
    """Resolve a :class:`SpecConfig` into a drafter instance.  The
    target model backs drafter="draft_model" when no draft config is
    given (self-drafting: the reference/parity setup)."""
    if spec.k < 1:
        raise ValueError(f"SpecConfig.k must be >= 1, got {spec.k}")
    if spec.drafter == "ngram":
        return NgramDrafter(spec.k, max_ngram=spec.max_ngram,
                            min_ngram=spec.min_ngram)
    if spec.drafter == "draft_model":
        cfg = spec.draft_cfg if spec.draft_cfg is not None else target_cfg
        params = spec.draft_params if spec.draft_params is not None \
            else target_params
        if cfg is None or params is None:
            raise ValueError("drafter='draft_model' needs draft_cfg/"
                             "draft_params (or a target model to self-"
                             "draft with)")
        return DraftModelDrafter(cfg, params, spec.k, policy=policy)
    raise ValueError(f"unknown drafter {spec.drafter!r}; "
                     f"one of ('ngram', 'draft_model')")
