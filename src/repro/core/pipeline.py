"""Multi-stage parallel processing — paper pillar P4 (Figure 4).

The paper splits serving into four OS processes: main, data preprocessing,
model inference, and post-processing, connected by queues.  JAX device
dispatch releases the GIL, so the identical dataflow runs here as *threads*
over bounded queues (see DESIGN.md §3.3 for the adaptation note): while the
accelerator runs batch N, the tokenizer stage prepares batch N+1 and the
detokenizer drains batch N-1.

``run_pipelined`` and ``run_sequential`` process the same work; the Table-1
benchmark measures the ratio.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

from repro.core.engine import InferenceEngine
from repro.core.sampling import SamplingParams
from repro.core.scheduler import DynamicBatcher, Request, pad_batch
from repro.core.tokenizer import FastTokenizer

_STOP = object()


@dataclass
class PipelineResult:
    uid: int
    text: str
    token_ids: List[int]


def _preprocess_worker(texts, tokenizer, batcher: DynamicBatcher,
                       out_q: "queue.Queue", max_new_tokens: int):
    """Stage 1: tokenize + dynamic batching."""
    for uid, text in enumerate(texts):
        batcher.add(Request(uid=uid, tokens=tokenizer.encode(text),
                            max_new_tokens=max_new_tokens))
    while True:
        batch = batcher.next_batch()
        if batch is None:
            break
        toks, lens = pad_batch(batch)
        out_q.put((batch, toks, lens))
    out_q.put(_STOP)


def _inference_worker(engine: InferenceEngine, sp: SamplingParams,
                      in_q: "queue.Queue", out_q: "queue.Queue"):
    """Stage 2: model prefill + decode."""
    while True:
        item = in_q.get()
        if item is _STOP:
            out_q.put(_STOP)
            return
        batch, toks, lens = item
        max_new = max(r.max_new_tokens for r in batch.requests)
        gen = engine.generate_batch(toks, lens, max_new, sp)
        out_q.put((batch, gen))


def _postprocess_worker(tokenizer, in_q: "queue.Queue",
                        results: List[PipelineResult]):
    """Stage 3: strip padding, detokenize."""
    while True:
        item = in_q.get()
        if item is _STOP:
            return
        batch, gen = item
        for i, r in enumerate(batch.requests):
            row = gen[i]
            ids = [int(t) for t in row[row >= 0]]
            results.append(PipelineResult(
                uid=r.uid, token_ids=ids,
                text=tokenizer.decode(ids) if tokenizer else ""))


def run_pipelined(texts: Sequence[str], tokenizer: Optional[FastTokenizer],
                  engine: InferenceEngine, *, max_new_tokens: int = 16,
                  sp: SamplingParams = SamplingParams(), max_batch: int = 8,
                  queue_depth: int = 4) -> List[PipelineResult]:
    """Paper Figure-4 topology: pre || infer || post as concurrent stages."""
    batcher = DynamicBatcher(max_batch=max_batch,
                             buckets=engine.prompt_buckets())
    q_pre = queue.Queue(maxsize=queue_depth)
    q_post = queue.Queue(maxsize=queue_depth)
    results: List[PipelineResult] = []
    threads = [
        threading.Thread(target=_preprocess_worker,
                         args=(texts, tokenizer, batcher, q_pre,
                               max_new_tokens)),
        threading.Thread(target=_inference_worker,
                         args=(engine, sp, q_pre, q_post)),
        threading.Thread(target=_postprocess_worker,
                         args=(tokenizer, q_post, results)),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    results.sort(key=lambda r: r.uid)
    return results


def run_sequential(texts: Sequence[str], tokenizer: Optional[FastTokenizer],
                   engine: InferenceEngine, *, max_new_tokens: int = 16,
                   sp: SamplingParams = SamplingParams(),
                   max_batch: int = 8) -> List[PipelineResult]:
    """The paper's pre-optimization flow: strictly sequential stages."""
    batcher = DynamicBatcher(max_batch=max_batch,
                             buckets=engine.prompt_buckets())
    for uid, text in enumerate(texts):
        batcher.add(Request(uid=uid, tokens=tokenizer.encode(text),
                            max_new_tokens=max_new_tokens))
    results: List[PipelineResult] = []
    while True:
        batch = batcher.next_batch()
        if batch is None:
            break
        toks, lens = pad_batch(batch)
        gen = engine.generate_batch(toks, lens, max_new_tokens, sp)
        for i, r in enumerate(batch.requests):
            row = gen[i]
            ids = [int(t) for t in row[row >= 0]]
            results.append(PipelineResult(
                uid=r.uid, token_ids=ids,
                text=tokenizer.decode(ids) if tokenizer else ""))
    results.sort(key=lambda r: r.uid)
    return results
