"""Batched inference engine — where the paper's pillars compose.

  * P1: KV-cache prefill/decode split, half-precision policy, buffer
    donation (decode updates the cache in place = Paddle "memory reuse").
  * P2: optionally runs a pruned model with id remapping at the boundary.
  * P4: dynamic length-bucketed batching via :class:`DynamicBatcher`.

Also provides the *baseline* path (``use_kv_cache=False``) that re-runs the
full forward for every generated token — the paper's Table-1 row 1 — so the
speedup of the optimized stack is measurable against it.
"""
from __future__ import annotations

import time
import warnings
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ATTN, HYBRID, MLSTM, MOE_FFN, SLSTM,
                                ModelConfig)
from repro.core import kv_cache as KV
from repro.core import prefix_cache as PC
from repro.core import pruning as PR
from repro.core.continuous import (ContinuousScheduler, FaultConfig,
                                   HostKVStore, PageAllocator, ServeMetrics)
from repro.core.precision import BF16, Policy, compress_weights
from repro.core.sampling import SamplingParams, sample, speculative_verify
from repro.core.speculative import SpecConfig, get_drafter
from repro.core.scheduler import (DEFAULT_BUCKETS, Batch, DynamicBatcher,
                                  Request, pad_batch, pick_bucket,
                                  truncate_prompt)
from repro.core.tokenizer import EOS
from repro.models import transformer as T


# Default per-iteration token budget of the unified scheduler: decode
# tokens from every live slot plus prefill-chunk tokens from admitting
# slots must fit under it, so a long prompt can never monopolize a step.
DEFAULT_MAX_BATCHED_TOKENS = 256


def mixed_width_buckets(budget: int) -> tuple:
    """Padded window widths the unified scheduler's mixed forwards are
    traced at: per-iteration chunk widths bucket up into this set, so
    the compiled-shape count stays bounded no matter how scheduling
    timing slices the prompts; the budget itself caps the set.  Exposed
    so benches can pre-warm every width (a chunk's width depends on how
    many slots were decoding when it was scheduled — i.e. on arrival
    timing — so a measured run may otherwise hit an uncompiled shape)."""
    return tuple(w for w in (8, 16, 32, 64, 128, 256, 512, 1024, 2048,
                             4096) if w < budget) + (budget,)


def packed_width_buckets(budget: int) -> tuple:
    """Stream widths the packed (1, T) dispatch is traced at.  The
    packed kernel only constrains T to multiples of its 8-lane query
    tile, so the stream buckets far finer than the power-of-two chunk
    widths: a <=32-shape ladder whose step scales with the budget keeps
    padded lanes near the ladder-step remainder (under 10% of stream
    lanes in steady state) without growing the compiled-shape count
    unboundedly.  Exposed so benches can pre-warm every stream width."""
    cap = -(-budget // 8) * 8                # budget, 8-lane aligned
    step = max(8, -(-(cap // 32) // 8) * 8)  # ~cap/32, 8-lane aligned
    return tuple(sorted({min(i * step, cap)
                         for i in range(1, -(-cap // step) + 1)}))


@dataclass
class EngineStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    nocache_s: float = 0.0
    prompt_tokens: int = 0
    generated_tokens: int = 0
    batches: int = 0

    def merge(self, other: "EngineStats"):
        for f in self.__dataclass_fields__:
            setattr(self, f, getattr(self, f) + getattr(other, f))


class InferenceEngine:
    """Single-host serving engine for one model (single-stream vocab).

    Multi-codebook (audio) models are served through ``launch/serve.py``'s
    serve_step directly; this engine covers the text path the paper targets.
    """

    def __init__(self, cfg: ModelConfig, params, *, policy: Policy = BF16,
                 max_batch: int = 8, max_len: int = 512,
                 use_kv_cache: bool = True, donate: bool = True,
                 prune_maps: Optional[PR.PruneMaps] = None, seed: int = 0):
        self.cfg = cfg
        self.policy = policy
        self.params = policy.cast_params(params)
        # serve-time weight compression (weights_dtype axis): quantize /
        # recast the dense serve-path matmul weights AFTER cast_params
        # (which would recast the fp32 scales of a quantized tree).
        # Timed so the serve trace can carry a load-time span.
        t_q = time.perf_counter()
        self.params, self.weight_stats = compress_weights(self.params,
                                                          policy)
        jax.block_until_ready(self.params)
        self.weight_quant_s = time.perf_counter() - t_q
        self.max_batch = max_batch
        self.max_len = max_len
        self.use_kv_cache = use_kv_cache
        self.prune_maps = prune_maps
        self.rng = jax.random.PRNGKey(seed)
        self.stats = EngineStats()
        self._donate = donate
        self._cont_cache = {}          # (sp, steps) -> jitted fns
        self._paged_ctx = None         # persistent paged pool + radix trie

        def prefill_fn(params, tokens, lengths, cache):
            return T.forward_prefill(params, cfg, tokens, lengths, cache,
                                     policy=policy, max_len=max_len)

        def decode_fn(params, tokens, cache, lengths):
            return T.forward_decode(params, cfg, tokens, cache, lengths,
                                    policy=policy, max_len=max_len)

        def full_fn(params, tokens):
            return T.forward_train(params, cfg, tokens, policy=policy,
                                   remat=False)[0]

        def decode_n_fn(params, first_tok, cache, lengths, n_steps):
            """Fused greedy decode loop (beyond-paper): one compiled
            lax.scan instead of n host dispatches — removes per-token
            launch overhead, keeps the cache update in place."""

            def body(carry, _):
                tok, cache, lens, done = carry
                logits, cache = T.forward_decode(params, cfg, tok[:, None],
                                                 cache, lens, policy=policy,
                                                 max_len=max_len)
                nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                nxt = jnp.where(done, 0, nxt)
                done = done | (nxt == EOS)     # EOS itself is not emitted
                emit = jnp.where(done, -1, nxt)
                return (nxt, cache, lens + 1, done), emit

            B = first_tok.shape[0]
            done0 = first_tok == EOS
            carry = (jnp.where(done0, 0, first_tok), cache, lengths, done0)
            carry, emitted = jax.lax.scan(body, carry, None, length=n_steps)
            return emitted.T, carry[1]                    # (B, n), cache

        dn = (3,) if donate else ()
        self._prefill = jax.jit(prefill_fn, donate_argnums=dn)
        self._decode = jax.jit(decode_fn,
                               donate_argnums=(2,) if donate else ())
        self._decode_n = jax.jit(decode_n_fn, static_argnums=(4,),
                                 donate_argnums=(2,) if donate else ())
        self._full = jax.jit(full_fn)

    # ------------------------------------------------------------------
    def prompt_buckets(self):
        """Prompt length buckets bounded by the engine context: max_len is
        always the final bucket, so prompts that fit are never truncated
        below it and prompts beyond it can't silently overflow the cache."""
        return tuple(b for b in DEFAULT_BUCKETS if b < self.max_len) \
            + (self.max_len,)

    # ------------------------------------------------------------------
    def generate_batch(self, tokens: np.ndarray, lengths: np.ndarray,
                       max_new_tokens: int,
                       sp: SamplingParams = SamplingParams(),
                       stop_at_eos: bool = True) -> np.ndarray:
        """tokens: (B, L) right-padded int32. Returns (B, max_new) ids
        (PAD-filled after EOS)."""
        if self.prune_maps is not None:
            tokens = PR.remap_tokens(tokens, self.prune_maps)
        if self.use_kv_cache:
            out = self._generate_kv(tokens, lengths, max_new_tokens, sp,
                                    stop_at_eos)
        else:
            out = self._generate_nocache(tokens, lengths, max_new_tokens, sp,
                                         stop_at_eos)
        if self.prune_maps is not None:
            out = PR.unmap_tokens(np.maximum(out, 0), self.prune_maps) \
                * (out >= 0) + out * (out < 0)
        return out

    # -- prefix caching (paper §1: "extracted relevant content offline") --
    def set_prefix(self, prefix_tokens, *, page_size: int = 16,
                   num_pages: Optional[int] = None,
                   slots: Optional[int] = None) -> None:
        """Prefill a shared prompt prefix into the paged pool ONCE and
        pin it in the radix prefix cache: every later request admitted by
        :meth:`serve_continuous` that starts with these tokens maps the
        prefix pages zero-copy and only prefills its own suffix.

        Only layer families that support page sharing can be seeded (see
        ``prefix_cache.shareable``); for opted-out families this warns
        and is a no-op — serving stays correct, just without reuse.  The
        geometry arguments must match the later ``serve_continuous`` call
        (they share the persistent pool).
        """
        toks = [int(t) for t in prefix_tokens]
        reason = PC.shareable(self.cfg, self.max_len)
        if reason is not None:
            warnings.warn(f"set_prefix: prefix sharing disabled — {reason}")
            return
        if len(toks) > self.max_len - 1:
            raise ValueError(f"prefix of {len(toks)} tokens leaves no room "
                             f"to generate within max_len={self.max_len}")
        ctx = self._paged_context(page_size, num_pages, slots)
        n = -(-len(toks) // page_size)
        pages = ctx["alloc"].alloc(n)
        if pages is None:
            ctx["trie"].evict(n - ctx["alloc"].free_count)
            pages = ctx["alloc"].alloc(n)
        if pages is None:
            raise ValueError(f"prefix needs {n} pages; pool has only "
                             f"{ctx['alloc'].free_count} free")
        seed = self._cont_cache.get("seed")
        if seed is None:
            cfg, policy, max_len = self.cfg, self.policy, self.max_len

            def seed_fn(params, tokens, length, block_row, pages_a, cache):
                cache = KV.reset_pages_all(cache, pages_a)
                view = KV.slot_view(cache, 1)
                paged = {"block_tables": block_row,
                         "active": jnp.ones((1,), bool)}
                _, view = T.forward_prefill(
                    params, cfg, tokens, length, view, policy=policy,
                    max_len=max_len, last_only=True, paged=paged)
                return KV.slot_merge(cache, view,
                                     jnp.zeros((1,), jnp.int32))

            seed = jax.jit(seed_fn,
                           donate_argnums=(5,) if self._donate else ())
            self._cont_cache["seed"] = seed
        row = np.full((1, ctx["pages_per_slot"]), -1, np.int32)
        row[0, :n] = pages
        pages_a = np.full((1, ctx["pages_per_slot"]), ctx["dump"], np.int32)
        pages_a[0, :n] = pages
        ctx["cache"] = seed(self.params,
                            jnp.asarray([toks], jnp.int32),
                            jnp.asarray([len(toks)], jnp.int32),
                            jnp.asarray(row), jnp.asarray(pages_a),
                            ctx["cache"])
        jax.block_until_ready(ctx["cache"]["layers"])
        # the trie takes its own reference on retained pages; ours drops
        ctx["trie"].insert(toks, pages, len(toks), pin=True)
        for p in pages:
            ctx["alloc"].decref(p)

    def clear_prefix(self) -> None:
        """Unpin all seeded prefixes: their pages stay cached but become
        ordinary LRU-evictable radix entries."""
        if self._paged_ctx is not None:
            self._paged_ctx["trie"].unpin_all()

    def reset_prefix_cache(self) -> None:
        """Drop the persistent paged pool and radix trie entirely (cold
        cache).  Jitted functions are kept, so the next serve pays no
        retrace — benchmarks use this to measure cold-trie serving with
        warm compilation."""
        self._paged_ctx = None

    # -- optimized path (P1) --------------------------------------------
    def _generate_kv(self, tokens, lengths, max_new, sp, stop_at_eos):
        B = tokens.shape[0]
        cache = T.init_cache(self.cfg, B, self.max_len,
                             self.policy.kv_cache_dtype(dense=True))
        t0 = time.perf_counter()
        toks = jnp.asarray(tokens, jnp.int32)
        lens = jnp.asarray(lengths, jnp.int32)
        logits, cache = self._prefill(self.params, toks,
                                      jnp.asarray(lengths, jnp.int32),
                                      cache)
        logits = jax.block_until_ready(logits)
        t1 = time.perf_counter()

        out = np.full((B, max_new), -1, np.int64)
        # logits cover the suffix only; last real token is suffix-local
        last = logits[jnp.arange(B), jnp.asarray(lengths, jnp.int32) - 1]
        self.rng, sub = jax.random.split(self.rng)
        first = sample(last, sub, sp)

        if sp.temperature <= 0.0 and max_new > 1 and stop_at_eos:
            # fused greedy loop: a single compiled scan over the steps;
            # `first` sits at absolute position `lens`
            first_np = np.asarray(first)
            out[:, 0] = np.where(first_np == EOS, -1, first_np)
            emitted, cache = self._decode_n(self.params, first, cache,
                                            lens, max_new - 1)
            out[:, 1:] = np.asarray(emitted)
        else:
            done = np.zeros((B,), bool)
            nxt = first
            for step in range(max_new):
                nxt_np = np.asarray(nxt)
                if stop_at_eos:
                    done |= nxt_np == EOS
                out[~done, step] = nxt_np[~done]
                if done.all() or step == max_new - 1:
                    break
                logits1, cache = self._decode(self.params, nxt[:, None],
                                              cache, lens + step)
                self.rng, sub = jax.random.split(self.rng)
                nxt = sample(logits1[:, 0], sub, sp)
        jax.block_until_ready(cache["layers"])
        t2 = time.perf_counter()
        self.stats.merge(EngineStats(
            prefill_s=t1 - t0, decode_s=t2 - t1,
            prompt_tokens=int(lengths.sum()),
            generated_tokens=int((out >= 0).sum()), batches=1))
        return out

    # -- paper Table-1 baseline: no KV cache ------------------------------
    def _generate_nocache(self, tokens, lengths, max_new, sp, stop_at_eos):
        B, L = tokens.shape
        total = L + max_new
        buf = np.zeros((B, total), np.int32)
        buf[:, :L] = tokens
        lens = np.asarray(lengths).copy()
        out = np.full((B, max_new), -1, np.int64)
        done = np.zeros((B,), bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            logits = self._full(self.params, jnp.asarray(buf))
            last = logits[jnp.arange(B), jnp.asarray(lens - 1)]
            self.rng, sub = jax.random.split(self.rng)
            nxt = np.asarray(sample(last, sub, sp))
            if stop_at_eos:
                done |= nxt == EOS
            out[~done, step] = nxt[~done]
            buf[np.arange(B), lens] = np.where(done, 0, nxt)
            lens = lens + (~done).astype(lens.dtype)
            if done.all():
                break
        t1 = time.perf_counter()
        self.stats.merge(EngineStats(
            nocache_s=t1 - t0, prompt_tokens=int(np.sum(lengths)),
            generated_tokens=int((out >= 0).sum()), batches=1))
        return out

    # -- continuous batching (paged KV, in-flight admission) --------------
    def _paged_context(self, page_size: int, num_pages: Optional[int],
                       slots: Optional[int]) -> dict:
        """The persistent paged serving context: pool arrays, refcounted
        allocator, and the radix prefix trie.  It survives across
        ``serve_continuous`` calls (and is what ``set_prefix`` seeds), so
        cached prefixes keep paying off run after run.  A geometry change
        rebuilds it from scratch (dropping any cached prefixes, loudly).

        Pool storage follows ``policy.kv_dtype``: int8 halves K/V bytes
        per token (pool sizing below accounts for the parallel scale
        pools), so the same byte budget holds ~2x the pages.
        """
        slots = slots or self.max_batch
        pages_per_slot = -(-self.max_len // page_size)
        if num_pages is None:
            num_pages = slots * pages_per_slot
        key = (page_size, num_pages, slots)
        if self._paged_ctx is not None and self._paged_ctx["key"] == key:
            return self._paged_ctx
        if self._paged_ctx is not None:
            warnings.warn(
                f"paged pool geometry changed {self._paged_ctx['key']} -> "
                f"{key}; rebuilding (cached prefixes are dropped)")
        kv_dtype = self.policy.kv_dtype
        if kv_dtype == "int8" and not any(
                spec.mixer == ATTN
                for stack in self.cfg.stacks for spec in stack.pattern):
            warnings.warn("kv_dtype=int8 requested but no attention layer "
                          "has a paged pool to quantize; state stays at "
                          "full precision")
        alloc = PageAllocator(num_pages)
        cache = T.init_paged_cache(
            self.cfg, num_pages=num_pages, page_size=page_size,
            max_slots=slots, max_len=self.max_len,
            dtype=self.policy.compute_dtype, kv_dtype=kv_dtype)
        pool_bytes = KV.paged_pool_bytes(cache)
        self._paged_ctx = {
            "key": key, "page_size": page_size, "num_pages": num_pages,
            "slots": slots, "pages_per_slot": pages_per_slot,
            "dump": num_pages, "alloc": alloc,
            "kv_dtype": kv_dtype,
            "kv_pool_bytes": pool_bytes,
            # per token of pool capacity (incl. the dump page), summed
            # over layers — scale pools and position bookkeeping included
            "kv_bytes_per_token": pool_bytes / ((num_pages + 1) * page_size),
            "trie": PC.RadixPrefixCache(alloc, page_size),
            "cache": cache,
        }
        return self._paged_ctx

    def _continuous_fns(self, sp: SamplingParams, steps_per_sync: int):
        """Build (once per (sp, steps) combo) the jitted entry points of
        the continuous path.  ``step`` drives every decode-only sync;
        the admit functions are the *bucketed fallback* for layer
        families the unified chunked scheduler cannot serve (ring /
        recurrent / MLA state — see ``serve_continuous``):

        * admit: bucket-padded prefill of a batch of same-bucket requests
          that scatters K/V straight into their freshly allocated pool
          pages (and resets those pages' stale positions), merges dense
          per-slot state into the slot rows, and samples each first
          token — one dispatch per admission group.
        * admit_prefix: the radix-cache variant — copies each request's
          partial tail page (copy-on-write; shared pages are never
          written), prefills only the *unmatched suffix* from its per-row
          start offset, and attends over the gathered block table so the
          suffix sees the shared prefix KV it never computed.
        * step: a lax.scan fusing ``steps_per_sync`` iterations of
          [decode all slots -> sample on device -> scatter KV into pages],
          so the sampled path costs one host round-trip per *sync*, not
          per token.
        """
        key = (sp, steps_per_sync)
        cached = self._cont_cache.get(key)
        if cached is not None:
            return cached
        cfg, policy, max_len = self.cfg, self.policy, self.max_len

        def admit_fn(params, tokens, length, slot, block_row, pages, cache,
                     rng):
            cache = KV.reset_pages_all(cache, pages)
            view = KV.slot_view(cache, tokens.shape[0])
            paged = {"block_tables": block_row,
                     "active": jnp.ones((tokens.shape[0],), bool)}
            logits, view = T.forward_prefill(params, cfg, tokens, length,
                                             view, policy=policy,
                                             max_len=max_len, last_only=True,
                                             paged=paged)
            cache = KV.slot_merge(cache, view, slot)
            rng, sub = jax.random.split(rng)
            first = sample(logits[:, 0], sub, sp)
            return first, cache, rng

        def admit_prefix_fn(params, tokens, length, start, slot, block_row,
                            pages, cow_src, cow_dst, cow_keep, cache, rng):
            # order matters: reset the fresh pages' stale positions, THEN
            # copy-on-write the partial tail (the destination is one of
            # the fresh pages), THEN prefill the suffix into it
            cache = KV.reset_pages_all(cache, pages)
            cache = KV.copy_pages_all(cache, cow_src, cow_dst, cow_keep)
            view = KV.slot_view(cache, tokens.shape[0])
            paged = {"block_tables": block_row,
                     "active": jnp.ones((tokens.shape[0],), bool)}
            logits, view = T.forward_prefill(params, cfg, tokens, length,
                                             view, policy=policy,
                                             max_len=max_len, last_only=True,
                                             start=start, paged=paged)
            cache = KV.slot_merge(cache, view, slot)
            rng, sub = jax.random.split(rng)
            first = sample(logits[:, 0], sub, sp)
            return first, cache, rng

        def step_fn(params, tok, lens, rem, act, block_tables, cache, rng):
            paged = {"block_tables": block_tables}

            def body(carry, _):
                tok, lens, rem, act, cache, rng = carry
                logits, cache = T.forward_decode(
                    params, cfg, tok[:, None], cache, lens, policy=policy,
                    max_len=max_len, paged={**paged, "active": act})
                rng, sub = jax.random.split(rng)
                nxt = sample(logits[:, 0], sub, sp)
                is_eos = nxt == EOS                  # EOS is not emitted
                emit = jnp.where(act & ~is_eos, nxt, -1)
                still = act & ~is_eos & (rem > 1)
                lens = lens + act.astype(lens.dtype)
                rem = rem - act.astype(rem.dtype)
                tok = jnp.where(still, nxt, tok)
                return (tok, lens, rem, still, cache, rng), (emit, act)

            carry, (emits, acts) = jax.lax.scan(
                body, (tok, lens, rem, act, cache, rng), None,
                length=steps_per_sync)
            tok, lens, rem, act, cache, rng = carry
            return tok, lens, rem, act, cache, rng, emits.T, acts.T

        fns = (jax.jit(admit_fn,
                       donate_argnums=(6,) if self._donate else ()),
               jax.jit(admit_prefix_fn,
                       donate_argnums=(10,) if self._donate else ()),
               jax.jit(step_fn,
                       donate_argnums=(6,) if self._donate else ()))
        self._cont_cache[key] = fns
        return fns

    def _mixed_fns(self, sp: SamplingParams):
        """Build (once per sp) the chunk entry point of the unified
        iteration: one jitted dispatch per scheduled prefill chunk.
        Fresh pages of a slot running its first chunk are reset and its
        partial COW tail page copied in the same call (dump-page no-ops
        otherwise), then the chunk window is scattered into the paged
        pool and attended in a single mixed forward
        (``T.forward_mixed``), and the row's last-token logits are
        sampled on device (consumed only by a prompt's final chunk).
        Retraced once per padded window-width bucket, so the
        compiled-shape set stays small regardless of scheduling timing
        — this replaces the per-(B, bucket) power-of-two
        admission-chunk machinery on chunked families.
        """
        key = ("mixed", sp)
        cached = self._cont_cache.get(key)
        if cached is not None:
            return cached
        cfg, policy, max_len = self.cfg, self.policy, self.max_len

        def mixed_fn(params, tokens, row_start, n_q, block_tables,
                     reset_rows, cow_src, cow_dst, cow_keep, cache, rng):
            cache = KV.reset_pages_all(cache, reset_rows)
            cache = KV.copy_pages_all(cache, cow_src, cow_dst, cow_keep)
            logits, cache = T.forward_mixed(
                params, cfg, tokens, cache, row_start, n_q, policy=policy,
                max_len=max_len, paged={"block_tables": block_tables})
            rng, sub = jax.random.split(rng)
            nxt = sample(logits[:, 0], sub, sp)
            return nxt, cache, rng

        fn = jax.jit(mixed_fn, donate_argnums=(9,) if self._donate else ())
        self._cont_cache[key] = fn
        return fn

    def _packed_fns(self, sp: SamplingParams):
        """Build (once per sp) the token-packed iteration entry point:
        a WHOLE scheduler iteration — every decoding slot's token plus
        every scheduled prefill-chunk token, flattened into one (1, T)
        ragged stream — as ONE jitted dispatch.  Page resets and COW
        tail copies for every admitting slot in the iteration are fused
        in (dump-page no-ops otherwise), the stream's K/V is scattered
        per-token into each lane's own slot pages, each query attends
        its slot's paged history under its own causal mask
        (``T.forward_packed``), and sampling runs fused on every
        segment's last token.  Retraced once per global stream-width
        bucket (:func:`packed_width_buckets`) — dispatches per mixed
        iteration drop from ``1 + #chunks`` to exactly 1, and
        padded-lane waste is the ladder-step remainder instead of
        per-chunk width padding."""
        key = ("packed", sp)
        cached = self._cont_cache.get(key)
        if cached is not None:
            return cached
        cfg, policy, max_len = self.cfg, self.policy, self.max_len

        def packed_fn(params, tokens, slot_ids, positions, meta, seg_last,
                      block_tables, reset_rows, cow_src, cow_dst, cow_keep,
                      cache, rng):
            cache = KV.reset_pages_all(cache, reset_rows)
            cache = KV.copy_pages_all(cache, cow_src, cow_dst, cow_keep)
            logits, cache = T.forward_packed(
                params, cfg, tokens, cache, slot_ids, positions, seg_last,
                policy=policy, max_len=max_len,
                paged={"block_tables": block_tables, "packed_meta": meta})
            rng, sub = jax.random.split(rng)
            nxt = sample(logits[0], sub, sp)        # (S,)
            return nxt, cache, rng

        fn = jax.jit(packed_fn,
                     donate_argnums=(11,) if self._donate else ())
        self._cont_cache[key] = fn
        return fn

    def _spec_fns(self, sp: SamplingParams, k: int):
        """Build (once per (sp, k)) the jitted draft-verify decode step:
        ONE target forward scores the pending token plus ``k`` drafted
        tokens per slot against the paged pools (multi-token KV write +
        multi-query paged attention), the rejection sampler keeps the
        longest valid prefix per slot (exact-match greedy at temperature
        0), EOS/budget clamps are applied on device, and the rejected
        tail's KV entries are rewound (``paged_truncate_all``) before
        anything downstream — retire-time prefix-cache inserts in
        particular — can observe them."""
        key = ("spec", sp, k)
        cached = self._cont_cache.get(key)
        if cached is not None:
            return cached
        cfg, policy, max_len = self.cfg, self.policy, self.max_len

        def verify_fn(params, tok, lens, rem, act, drafts, block_tables,
                      cache, rng):
            K = drafts.shape[1]
            toks_in = jnp.concatenate([tok[:, None], drafts], axis=1)
            logits, cache = T.forward_verify(
                params, cfg, toks_in, cache, lens, policy=policy,
                max_len=max_len,
                paged={"block_tables": block_tables, "active": act})
            rng, sub = jax.random.split(rng)
            accept_len, nxt = speculative_verify(logits, drafts, sub, sp)
            # the step's nominal emit stream: the accepted drafts
            # verbatim, then the corrective/bonus token at index
            # accept_len — each element exactly distributed as
            # sequential sampling (greedy: each is an argmax)
            idx1 = jnp.arange(K + 1)[None, :]
            stream = jnp.concatenate(
                [drafts, jnp.zeros_like(nxt[:, None])], axis=1)
            stream = jnp.where(idx1 == accept_len[:, None], nxt[:, None],
                               stream)                          # (B, K+1)
            # budget truncation keeps a PREFIX of the stream (the last
            # budgeted token is the accepted draft itself — recomputing
            # a prediction at the clamped position would skip ahead)
            limit = jnp.minimum(accept_len + 1, jnp.maximum(rem, 0))
            # EOS anywhere in the emittable prefix ends the request
            # there; EOS itself is never emitted
            eos_hit = (stream == EOS) & (idx1 < limit[:, None])
            eos_pos = jnp.min(jnp.where(eos_hit, idx1, K + 1), axis=1)
            n_emit = jnp.where(act, jnp.minimum(limit, eos_pos), 0)
            done = eos_pos < limit
            emits = jnp.where(idx1 < n_emit[:, None], stream, -1)
            # written accepted context = pending token + the drafts that
            # were emitted (a trailing emitted `nxt` is pending, not yet
            # written — it lands at new_lens on the next step)
            d_count = jnp.minimum(accept_len, n_emit)
            new_lens = lens + jnp.where(act, d_count + 1, 0)
            new_rem = rem - n_emit.astype(rem.dtype)
            still = act & ~done & (new_rem > 0)
            tok = jnp.where(still, nxt, tok)
            # rewind rejected/stale entries: after this, every stored
            # position < new_lens holds final accepted context and
            # nothing at or beyond it is visible
            cache = KV.paged_truncate_all(cache, block_tables, new_lens)
            return (tok, new_lens, new_rem, still, cache, rng, emits,
                    jnp.where(act, d_count, 0))

        fn = jax.jit(verify_fn,
                     donate_argnums=(7,) if self._donate else ())
        self._cont_cache[key] = fn
        return fn

    def serve_continuous(self, requests: List[Request],
                         sp: SamplingParams = SamplingParams(), *,
                         page_size: int = 16,
                         num_pages: Optional[int] = None,
                         slots: Optional[int] = None,
                         steps_per_sync: int = 4,
                         arrivals: Optional[List[float]] = None,
                         prefix_cache: Optional[bool] = None,
                         spec: Optional[SpecConfig] = None,
                         max_batched_tokens: Optional[int] = None,
                         chunked_prefill: Optional[bool] = None,
                         packed: Optional[bool] = None,
                         preemption: str = "off",
                         max_preemptions: int = 2,
                         host_kv_bytes: Optional[int] = None,
                         faults: Optional[FaultConfig] = None,
                         debug_audit: bool = False,
                         trace=None):
        """Serve requests with continuous batching over a paged KV cache.

        Unlike :meth:`serve` (sort -> bucket -> drain), decode slots are
        persistent: a request is admitted into a free slot the moment one
        exists (and the page pool can hold its worst-case context), and
        is retired at EOS — other slots never wait for it.  KV lives in
        ``num_pages`` refcounted shared pages; per-request pages are
        allocated at admission and released at retirement.

        chunked_prefill / max_batched_tokens: the unified token-budget
        scheduler.  Instead of dispatching each admitted prompt as one
        whole-prompt prefill (which stalls every decoding slot for the
        prompt's full forward), each iteration packs one decode token
        per live slot plus up to the remaining budget in prefill-chunk
        tokens from admitting slots (FCFS) — executed as one fused
        decode dispatch plus packed per-chunk mixed forwards, so
        iteration compute tracks the budget's real token count and
        prompts prefill in budget-bounded chunks interleaved with
        decode, bounding inter-token latency.  With speculation, the
        budget also covers the verify step (the largest iteration:
        k+1 tokens per slot); iterations that carry prefill chunks
        pause drafting and charge one decode token per slot.  ``chunked_prefill=None``
        (default) enables it for the layer families that support it
        (paged pure non-windowed attention — the prefix-sharing gate;
        chunk attention needs per-position paged history, which
        ring/recurrent/MLA state does not expose), falling back to
        bucketed whole-prompt admission elsewhere; True warns and falls
        back on unsupported families; False forces the bucketed path.
        ``max_batched_tokens`` (default 256) is clamped up to one token
        per slot (k+1 under speculation) so decode can always step.
        Decode-only iterations still fuse ``steps_per_sync`` steps into
        one dispatch.  Greedy outputs are bit-identical chunked or not;
        pool dtypes narrower than the compute dtype (int8 aside, which
        always round-trips the pool) may flip near-tie greedy picks
        because chunk queries attend the written pool bytes.

        Requests that arrive faster than slots/pages free up queue FCFS,
        exactly as before — the budget only reshapes *how* an admitted
        prompt's prefill is scheduled.

        packed: token-packed ragged execution of mixed iterations.  The
        iteration's decode tokens and prefill-chunk tokens are
        flattened into one (1, T) stream (decode lanes first, then FCFS
        chunks) and the whole iteration runs as ONE dispatch — per-token
        KV scatter, per-segment causal attention against each lane's
        own slot pages, fused sampling on every segment's last token.
        T pads to one global bucket, so dispatches per mixed iteration
        drop from ``1 + #chunks`` to 1 and padded-lane waste is the
        bucket remainder rather than per-chunk width padding.  ``None``
        (default) enables it whenever the unified chunked scheduler is
        on (same family gate); False keeps the legacy
        decode-micro-step + per-chunk dispatches (the A/B baseline);
        True warns and falls back where chunking itself is unsupported.
        Greedy outputs are bit-identical packed or bucketed.


        prefix_cache: share identical prompt-prefix pages across requests
        through a radix trie (copy-on-write; zero prefill cost for the
        matched span).  None (default) enables it whenever every layer
        family supports sharing (see ``prefix_cache.shareable``); True
        warns and falls back to unshared serving for opted-out families;
        False disables matching (the pool still evicts stale cached
        prefixes under pressure).  Results are exact either way.

        arrivals: optional per-request arrival offsets in seconds (same
        order as ``requests``) for open-loop traces; requests only become
        admissible once their arrival time has passed.

        spec: a :class:`~repro.core.speculative.SpecConfig` enables
        draft–verify decoding: each decode step drafts ``spec.k`` tokens
        per slot (host-side), verifies them in ONE multi-token forward,
        and accepts the longest valid prefix — distribution preserving,
        bit-identical under greedy.  Requires the same layer families as
        prefix sharing (pure non-windowed attention; ring overwrites and
        recurrent state cannot be rolled back on rejection) — elsewhere
        it warns and serves non-speculatively.  ``steps_per_sync`` is
        ignored in speculative mode: drafting needs the emitted history
        after every verify, so each step is one host sync.

        preemption / host_kv_bytes: overload survivability.  With
        ``preemption`` "lru" (victim = most recently admitted) or
        "priority" (victim = lowest ``Request.priority``, strictly below
        the blocked head's), an admission that fails for *pages* while a
        slot is free evicts a decoding victim: its paged KV is
        snapshotted into a host-memory :class:`HostKVStore` of
        ``host_kv_bytes`` capacity (when set), its device pages are
        freed, and the request re-queues at the back with its generated
        tokens preserved.  On re-admission the snapshot is restored and
        decoding resumes bit-identically; if the host tier was full the
        context (prompt + generated tokens) is re-prefilled instead —
        same greedy stream, paid in compute.  The prefix trie spills
        evicted full-page leaves into the same tier and re-promotes them
        on a match.  Preemption requires the unified chunked scheduler
        (resume re-prefill rides the chunk machinery) and is disabled,
        loudly, on bucketed-only families.  ``max_preemptions`` bounds
        per-request churn: a request evicted that many times keeps its
        slot thereafter.

        Deadlines: ``Request.deadline`` (absolute seconds on the serve
        clock — the arrivals timeline) and ``Request.max_queue_wait``
        cancel still-queued work once expired (structured ``timed_out``
        outcome; a preempted request's generated tokens survive as a
        partial result).  Running slots are never cancelled — a request
        that finishes past its deadline completes and counts a deadline
        miss.  Every submitted request ends with a terminal
        ``Request.outcome``.

        faults / debug_audit: deterministic fault injection (see
        :class:`~repro.core.continuous.FaultConfig`) and a per-iteration
        allocator + host-tier audit for the overload test harness.

        trace: an optional :class:`~repro.core.trace.ServeTracer`.  When
        attached, the serve loop emits a structured event timeline —
        per-iteration records (budget use, decode lanes vs. chunk
        segments, packed width/padding, host/device wall split, pool
        gauges), request lifecycle events (enqueue → admit →
        prefill_chunk → first_token → preempt/offload/restore → retire)
        and scheduler decisions (admission denials, victim choices,
        prefix/host-tier events) — and sources EVERY wall-clock reading
        from the tracer's injectable clock, so a deterministic fake
        clock yields byte-reproducible traces.  ``None`` (default) is
        zero-cost: every emit site is guarded.  Greedy outputs are
        bit-identical traced or not (tracing never touches device work).

        Returns (requests, ServeMetrics); ``r.result`` is filled like
        :meth:`serve`.
        """
        if arrivals is not None and len(arrivals) != len(requests):
            raise ValueError("arrivals must match requests 1:1")
        ctx = self._paged_context(page_size, num_pages, slots)
        slots, num_pages = ctx["slots"], ctx["num_pages"]
        pages_per_slot, dump = ctx["pages_per_slot"], ctx["dump"]
        trie = ctx["trie"]
        share_reason = PC.shareable(self.cfg, self.max_len)
        share = share_reason is None if prefix_cache is None \
            else bool(prefix_cache)
        if share and share_reason is not None:
            warnings.warn(f"prefix_cache requested but disabled — "
                          f"{share_reason}")
            share = False
        spec_on = spec is not None
        if spec_on:
            spec_reason = PC.shareable(self.cfg, self.max_len)
            if spec_reason is not None:
                warnings.warn("speculative decoding requested but "
                              f"disabled — {spec_reason}")
                spec_on = False
        drafter = verify_fn = None
        if spec_on:
            # one-entry cache keyed on the SpecConfig object itself (held
            # strongly, so `is` can never alias a recycled address): the
            # draft-model drafter carries jit caches worth keeping across
            # serve calls with the same spec
            cached = self._cont_cache.get("drafter")
            if cached is not None and cached[0] is spec:
                drafter = cached[1]
            else:
                drafter = get_drafter(spec, self.cfg, self.params,
                                      policy=self.policy)
                self._cont_cache["drafter"] = (spec, drafter)
            verify_fn = self._spec_fns(sp, drafter.k)
        # -- unified token-budget scheduler (chunked prefill) --------------
        # same family gate as prefix sharing: chunk queries attend the
        # already-written paged history, which ring/recurrent/MLA state
        # cannot expose; opted-out families keep bucketed admission.
        chunked = share_reason is None if chunked_prefill is None \
            else bool(chunked_prefill)
        if chunked and share_reason is not None:
            warnings.warn(f"chunked prefill requested but disabled — "
                          f"{share_reason}")
            chunked = False
        budget = max_batched_tokens or DEFAULT_MAX_BATCHED_TOKENS
        floor = slots * ((drafter.k + 1) if spec_on else 1)
        if chunked and budget < floor:
            warnings.warn(
                f"max_batched_tokens={budget} cannot cover one "
                f"{'verify window' if spec_on else 'decode token'} per "
                f"slot; raising to {floor}")
            budget = floor
        # -- overload survivability: preemption + host KV tier -------------
        if preemption not in ("off", "lru", "priority"):
            raise ValueError(f"unknown preemption policy {preemption!r}")
        if preemption != "off" and not chunked:
            warnings.warn("preemption requested but disabled — it needs "
                          "the unified chunked scheduler (resume "
                          "re-prefill rides the chunk machinery)")
            preemption = "off"
        host = None
        if host_kv_bytes is not None or (faults is not None
                                         and faults.host_full):
            hb = 0 if (faults is not None and faults.host_full) \
                else host_kv_bytes
            host = ctx.get("host")
            if host is None or host.max_bytes != hb:
                # spilled prefixes from a previous budget are dropped
                # with the old store; preempt blobs never outlive a call
                host = HostKVStore(hb)
                ctx["host"] = host
        # -- token-packed ragged execution ---------------------------------
        packed_on = chunked if packed is None else bool(packed)
        if packed_on and not chunked:
            warnings.warn("packed execution requested but disabled — it "
                          "rides the unified chunked scheduler"
                          + (f" ({share_reason})"
                             if share_reason is not None else ""))
            packed_on = False
        mixed_fn = self._mixed_fns(sp) if (chunked and not packed_on) \
            else None
        packed_fn = self._packed_fns(sp) if packed_on else None
        # the decode share of a mixed iteration is a single fused step
        step_fn1 = self._continuous_fns(sp, 1)[2] \
            if (chunked and not packed_on) else None
        # mixed forwards are traced per padded window width; bucket the
        # width so the compiled-shape set stays small and deterministic
        width_buckets = mixed_width_buckets(budget)
        packed_buckets = packed_width_buckets(budget)
        admit_fn, admit_prefix_fn, step_fn = \
            self._continuous_fns(sp, steps_per_sync)
        buckets = self.prompt_buckets()
        # Two layer families are sensitive to prompt padding (the dense
        # bucket path shares both limitations for ragged batches):
        # recurrent mixers fold PAD steps into their state, and
        # capacity-based MoE lets PAD tokens compete for expert slots.
        # Admit those architectures at exact prompt length instead — one
        # retrace per distinct length, but exact results.
        pad_sensitive = any(
            spec.mixer in (MLSTM, SLSTM, HYBRID) or spec.ffn == MOE_FFN
            for stack in self.cfg.stacks for spec in stack.pattern)

        cache = ctx["cache"]
        sched = ContinuousScheduler(slots, ctx["alloc"], page_size,
                                    max_pages_per_slot=pages_per_slot,
                                    prefix_cache=trie, match_prefix=share,
                                    preemption=preemption,
                                    max_preemptions=max_preemptions,
                                    trace=trace)

        # device closures for the host-side scheduler/trie: both always
        # see the *latest* cache pytree (restore rebinds it)
        def offload_fn(pages):
            return KV.offload_pages(cache, pages)

        def restore_fn(blob, pages):
            nonlocal cache
            cache = KV.restore_pages(cache, blob, pages)

        sched.host_store = host
        sched.offload_fn = offload_fn
        sched.restore_fn = restore_fn
        trie.host_store = host
        trie.offload_fn = offload_fn if host is not None else None
        # like offload_fn, the tracer must not outlive this call on the
        # persistent trie/host objects (reset alongside it below)
        trie.trace = trace
        if host is not None:
            host.trace = trace
        spill_base = trie.spilled_pages
        promote_base = sched.promoted_pages
        ws = self.weight_stats
        metrics = ServeMetrics(kv_dtype=ctx["kv_dtype"],
                               kv_pool_bytes=ctx["kv_pool_bytes"],
                               kv_bytes_per_token=ctx["kv_bytes_per_token"],
                               weight_dtype=ws["weights_dtype"],
                               weight_bytes=int(ws["weight_bytes"]),
                               weight_bytes_saved=int(
                                   ws["weight_bytes_saved"]),
                               spec_mode=drafter.name if spec_on else "off",
                               spec_k=drafter.k if spec_on else 0,
                               scheduler="unified" if chunked
                               else "bucketed",
                               max_batched_tokens=budget if chunked else 0)
        stats = EngineStats(batches=1)
        trie_base = trie.evicted_pages

        block_tables = np.full((slots, pages_per_slot), -1, np.int32)
        tok = np.zeros((slots,), np.int32)
        lens = np.zeros((slots,), np.int32)
        rem = np.zeros((slots,), np.int32)
        act = np.zeros((slots,), bool)
        rng = self.rng

        if faults is not None and faults.collapse_arrivals:
            arrivals = None            # adversarial burst: all at t=0
        order = sorted(range(len(requests)),
                       key=lambda i: arrivals[i]) if arrivals else \
            list(range(len(requests)))
        incoming = [(arrivals[i] if arrivals else 0.0, requests[i])
                    for i in order]
        fault_hold: List[int] = []     # pool pages a fault is squatting on
        # with a tracer attached, EVERY wall reading this loop takes comes
        # from its injectable clock — a deterministic fake clock therefore
        # reproduces the exact event stream, timestamps included
        tr = trace
        clk = tr.clock if tr is not None else time.perf_counter
        t0 = clk()
        if tr is not None:
            tr.set_origin(t0)
            # weight-compression state gauge at serve start, plus the
            # load-time quantization span (only when weights actually
            # compressed: the span's wall-clock duration would otherwise
            # break fake-clock byte-determinism for uncompressed runs)
            tr.emit("weights", 0.0, dtype=ws["weights_dtype"],
                    weight_bytes=int(ws["weight_bytes"]),
                    weight_bytes_dense=int(ws["weight_bytes_dense"]),
                    quantized_tensors=int(ws["n_quantized"]))
            if ws["n_quantized"]:
                tr.emit("span", 0.0, name="quantize_weights",
                        dur=float(self.weight_quant_s), track="load")

        def now():
            return clk() - t0

        # per-iteration accounting for the trace timeline: device time
        # accumulates across this iteration's spans, then one iteration
        # record carries the host/device split + pool gauges
        it_acc = {"t0": 0.0, "iter": 0, "device_s": 0.0}

        @contextmanager
        def dev_span(name, phase):
            """Time one blocking device dispatch — books the wall into
            ``stats.prefill_s``/``decode_s`` exactly like the inline
            timers it replaces, plus (when tracing) a named device-track
            span and the iteration's device share."""
            ts = clk()
            try:
                yield
            finally:
                dt = clk() - ts
                if phase == "prefill":
                    stats.prefill_s += dt
                else:
                    stats.decode_s += dt
                it_acc["device_s"] += dt
                if tr is not None:
                    tr.emit("span", t=ts - t0, name=name, dur=dt,
                            track="device")

        def emit_iteration(**kw):
            dev = it_acc["device_s"]
            it_acc["device_s"] = 0.0
            it_acc["iter"] += 1
            if tr is None:
                return
            t_it = it_acc["t0"]
            dur = max(0.0, now() - t_it)
            tr.emit("iteration", t=t_it, iter=it_acc["iter"] - 1, dur=dur,
                    host_s=max(0.0, dur - dev), device_s=dev,
                    budget=budget if chunked else 0,
                    pages_in_use=int(sched.allocator.allocated_count),
                    host_bytes=int(host.used_bytes) if host is not None
                    else 0,
                    trie_nodes=int(trie.num_nodes), **kw)

        def count_outcome(req):
            """Fold a request's terminal outcome into the run metrics —
            called exactly once per request, at its terminal point."""
            oc = req.outcome
            metrics.outcome_counts[oc.status] = \
                metrics.outcome_counts.get(oc.status, 0) + 1
            if oc.deadline_missed:
                metrics.deadline_misses += 1
            if oc.status == "timed_out":
                metrics.timed_out += 1
            elif oc.status == "rejected":
                metrics.rejected += 1

        def retire(slot):
            st = sched.retire(slot, now())
            block_tables[slot, :] = -1
            act[slot] = False
            metrics.retired += 1
            metrics.generated_tokens += len(st.request.result)
            count_outcome(st.request)
            # queue wait counts: latency is submission -> completion
            metrics.latency_s.append(st.finished_at - st.submitted_at)
            if tr is not None:
                oc = st.request.outcome
                tr.emit("retire", t=st.finished_at, uid=st.request.uid,
                        slot=int(slot), status=oc.status,
                        preemptions=int(oc.preemptions),
                        deadline_missed=bool(oc.deadline_missed),
                        latency_s=st.finished_at - st.submitted_at,
                        generated=len(st.request.result))

        def record_emit(st, n, t):
            """TTFT / ITL bookkeeping: ``n`` tokens appended to ``st`` at
            wall time ``t``.  A multi-token sync (fused decode scan,
            accepted speculation window) spreads its wall time evenly
            over the tokens it emitted — per-token arrival inside one
            dispatch is unobservable."""
            if n <= 0:
                return
            if st.last_token_at is None:
                # a slot's first emission is always the single admission /
                # final-chunk sample: it defines TTFT and no ITL gap
                assert n == 1, "first emission must be a single token"
                metrics.ttft_s.append(t - st.submitted_at)
                if tr is not None:
                    tr.emit("first_token", t=t, uid=st.request.uid,
                            ttft_s=t - st.submitted_at)
            else:
                metrics.itl_s.extend([(t - st.last_token_at) / n] * n)
            st.last_token_at = t

        def apply_decode_results(tok_d, lens_d, rem_d, act_d, emits):
            """Fold a decode/verify dispatch's device results back into
            the host slot arrays: append emits, record TTFT/ITL, retire
            finished slots."""
            nonlocal tok, lens, rem, act
            tok, lens, rem = (np.array(tok_d), np.array(lens_d),
                              np.array(rem_d))
            act_new = np.array(act_d)
            metrics.decode_tokens += int((emits >= 0).sum())
            t_now = now()
            for slot in list(sched.slots):
                st = sched.slots[slot]
                if not st.prefill_done:
                    continue        # admitting slot rode along inactive
                n_emit = 0
                for t in emits[slot]:
                    if t >= 0:
                        st.emitted.append(int(t))
                        n_emit += 1
                record_emit(st, n_emit, t_now)
                if not act_new[slot]:
                    retire(slot)
            act = act_new

        def decode_micro_step():
            """One 1-token decode dispatch over every live slot — the
            decode share of a mixed iteration (each decoding slot's
            budget cost is exactly one token, so admitting prompts can
            never starve decode).  Dispatch only: no host sync here —
            the caller folds the results in after the iteration's
            single coalesced fetch."""
            nonlocal cache, rng
            with dev_span("decode_micro", "decode"):
                (tok_d, lens_d, rem_d, act_d, cache, rng, emits,
                 acts) = step_fn1(self.params, jnp.asarray(tok),
                                  jnp.asarray(lens), jnp.asarray(rem),
                                  jnp.asarray(act),
                                  jnp.asarray(block_tables), cache, rng)
            metrics.steps += 1
            metrics.slot_steps_total += slots
            return tok_d, lens_d, rem_d, act_d, emits, acts

        def run_mixed(plan):
            """One mixed iteration, ONE host sync.  The decode
            micro-step and every prefill-chunk forward are dispatched
            back-to-back asynchronously; a single ``jax.device_get``
            then drains the iteration's scalar results (decode emits +
            final-chunk samples) before any bookkeeping runs.  The
            per-dispatch ``block_until_ready`` calls this replaces were
            the mixed path's dominant host-time term — the device sat
            idle between dispatches while the host did bookkeeping.

            Each chunk runs as one packed single-row mixed forward (page
            reset + COW copy fused into the slot's first chunk), so an
            iteration's prefill compute tracks the budget's *real*
            token count — decode rows never pad chunk-wide, chunk rows
            never pad slot-deep.  Chunk dispatches are (1, W-bucket)
            shaped: a small deterministic trace set regardless of how
            arrival timing slices the prompts.

            Bookkeeping replays the pre-coalescing order exactly —
            decode results first, then chunks in plan order — so greedy
            token streams, trie insertions, and allocator state stay
            bit-identical to the one-sync-per-dispatch loop.  Returns
            the total padded lanes across this plan's chunk dispatches
            (the iteration record's ``padded_lanes``)."""
            nonlocal cache, rng
            dec = decode_micro_step() if plan.decode_slots else None
            padded = 0
            finals = {}        # chunk index -> final-chunk logits handle
            inited = set()     # slots whose page init this plan consumed
            for ci, c in enumerate(plan.chunks):
                st = sched.slots[c.slot]
                W = pick_bucket(c.length, width_buckets)
                toks = np.zeros((1, W), np.int32)
                # st.ctx == the prompt, except on a recompute-resume
                # where it also replays the pre-preemption output
                toks[0, :c.length] = st.ctx[c.start:c.start + c.length]
                reset_row = np.full((1, pages_per_slot), dump, np.int32)
                cow_src = np.full((1,), dump, np.int32)
                cow_dst = np.full((1,), dump, np.int32)
                cow_keep = np.zeros((1,), np.int32)
                if st.needs_init and c.slot not in inited:
                    # page init rides the slot's FIRST chunk only —
                    # needs_init itself clears in the bookkeeping phase
                    inited.add(c.slot)
                    reset_row[0, :len(st.fresh_pages)] = st.fresh_pages
                    if st.cow_src >= 0:
                        # COW invariant: the destination must be private
                        if sched.allocator.refcount(st.fresh_pages[0]) != 1:
                            raise AssertionError(
                                "COW write target is a shared page")
                        cow_src[0] = st.cow_src
                        cow_dst[0] = st.fresh_pages[0]
                        cow_keep[0] = st.matched_len
                        metrics.cow_copies += 1
                with dev_span("chunk", "prefill"):
                    nxt, cache, rng = mixed_fn(
                        self.params, jnp.asarray(toks),
                        jnp.asarray([c.start], jnp.int32),
                        jnp.asarray([c.length], jnp.int32),
                        jnp.asarray(block_tables[c.slot:c.slot + 1]),
                        jnp.asarray(reset_row), jnp.asarray(cow_src),
                        jnp.asarray(cow_dst), jnp.asarray(cow_keep), cache,
                        rng)
                if tr is not None:
                    tr.emit_now("prefill_chunk", uid=st.request.uid,
                                slot=int(c.slot), start=int(c.start),
                                len=int(c.length))
                padded += W - c.length
                metrics.prefill_chunks += 1
                metrics.prefill_tokens += c.length
                metrics.prefill_padded += W
                # only a prompt's FINAL chunk consumes its sampled token
                if c.start + c.length >= st.ctx_len and not st.is_resume:
                    finals[ci] = nxt
            # the iteration's single device->host transfer: every
            # dispatch above is in flight; blocking time books into
            # prefill_s (async dispatches' device time lands on whoever
            # blocks — here, always this span)
            with dev_span("mixed_sync", "prefill"):
                sync = jax.device_get(
                    {"emits": None if dec is None else dec[4],
                     "acts": None if dec is None else dec[5],
                     "finals": finals})
            metrics.host_syncs += 1
            if dec is not None:
                tok_d, lens_d, rem_d, act_d = dec[:4]
                metrics.slot_steps_active += int(sync["acts"].sum())
                apply_decode_results(tok_d, lens_d, rem_d, act_d,
                                     np.asarray(sync["emits"]))
            for ci, c in enumerate(plan.chunks):
                st = sched.slots[c.slot]
                req = st.request
                if st.needs_init:
                    st.needs_init = False
                    sched.release_cow_source(st)
                st.prefill_pos = c.start + c.length
                if not st.prefill_done:
                    continue
                # final chunk: its last-token logits seeded sampling
                plen = st.ctx_len
                # newly produced page-aligned context KV joins the trie
                # now (the partial tail joins at retire, once decode can
                # no longer write into it)
                sched.insert_prefix(st, (plen // page_size) * page_size)
                if st.is_resume:
                    # recompute-resume: the next token was already
                    # sampled before the preemption — continue from it
                    # verbatim (greedy bit-identity) instead of the
                    # replayed final chunk's fresh sample
                    tok[c.slot] = st.resume_pending
                    lens[c.slot] = plen
                    rem[c.slot] = st.resume_rem
                    act[c.slot] = True
                    st.last_token_at = now()
                    continue
                first = int(np.asarray(sync["finals"][ci])[0])
                gen_budget = min(req.max_new_tokens, self.max_len - plen)
                if first != EOS and gen_budget > 0:
                    st.emitted.append(first)
                    record_emit(st, 1, now())
                if first == EOS or gen_budget <= 1:
                    retire(c.slot)
                else:
                    tok[c.slot] = first
                    lens[c.slot] = plen
                    rem[c.slot] = gen_budget - 1
                    act[c.slot] = True
            return padded

        def run_packed(plan):
            """One token-packed ragged iteration: the WHOLE plan — every
            decoding slot's token plus every scheduled prefill chunk —
            as ONE (1, T) dispatch.  T buckets on the plan's real token
            count (same width set the mixed forwards use), so the
            compiled-shape set stays small while padded lanes are the
            bucket remainder, not per-chunk width padding.  Greedy
            bookkeeping after the dispatch replicates the bucketed
            path's exactly (decode-step semantics for decode segments,
            final-chunk sampling/resume semantics for chunk segments),
            so outputs stay bit-identical between the two executions."""
            nonlocal cache, rng
            from repro.kernels import decode_attention as DA
            W = pick_bucket(plan.total_tokens, packed_buckets)
            pb = sched.pack_batch(plan, tok, lens, W)
            reset_rows = np.full((slots, pages_per_slot), dump, np.int32)
            cow_src = np.full((slots,), dump, np.int32)
            cow_dst = np.full((slots,), dump, np.int32)
            cow_keep = np.zeros((slots,), np.int32)
            for c in plan.chunks:
                st = sched.slots[c.slot]
                if not st.needs_init:
                    continue
                reset_rows[c.slot, :len(st.fresh_pages)] = st.fresh_pages
                if st.cow_src >= 0:
                    # COW invariant: the destination must be private
                    if sched.allocator.refcount(st.fresh_pages[0]) != 1:
                        raise AssertionError(
                            "COW write target is a shared page")
                    cow_src[c.slot] = st.cow_src
                    cow_dst[c.slot] = st.fresh_pages[0]
                    cow_keep[c.slot] = st.matched_len
                    metrics.cow_copies += 1
            # static per-W work-table height: every segment adds at most
            # one partial query block, so ceil-sum <= T/BQ + #segments
            n_work = W // DA.PACKED_BLOCK_Q + slots
            meta = DA.packed_meta_table(pb.seg_start[:pb.n_segments],
                                        pb.seg_len[:pb.n_segments],
                                        pb.seg_slots[:pb.n_segments],
                                        W, n_work)
            # one dispatch carries both shares; device_s sums both pools
            with dev_span("packed", "prefill"):
                nxt, cache, rng = packed_fn(
                    self.params, jnp.asarray(pb.tokens[None, :]),
                    jnp.asarray(pb.slot_ids), jnp.asarray(pb.positions),
                    jnp.asarray(meta), jnp.asarray(pb.last_idx),
                    jnp.asarray(block_tables), jnp.asarray(reset_rows),
                    jnp.asarray(cow_src), jnp.asarray(cow_dst),
                    jnp.asarray(cow_keep), cache, rng)
                nxt = np.asarray(jax.block_until_ready(nxt))
            metrics.host_syncs += 1
            metrics.steps += 1
            metrics.slot_steps_total += slots
            metrics.slot_steps_active += len(plan.decode_slots)
            metrics.mixed_iters += 1
            metrics.mixed_dispatches += 1
            metrics.packed_tokens_real += pb.n_tokens
            metrics.packed_tokens_padded += W
            real = sum(c.length for c in plan.chunks)
            metrics.prefill_chunks += len(plan.chunks)
            metrics.prefill_tokens += real
            metrics.prefill_padded += real   # pad is per-stream, not
            t_emit = now()                   # per-chunk — see packed_*
            for i in range(pb.n_decode):
                s = int(pb.seg_slots[i])
                st = sched.slots[s]
                v = int(nxt[i])
                lens[s] += 1
                rem[s] -= 1
                if v != EOS:
                    st.emitted.append(v)
                    record_emit(st, 1, t_emit)
                    metrics.decode_tokens += 1
                if v == EOS or rem[s] <= 0:
                    retire(s)
                else:
                    tok[s] = v
            for i in range(pb.n_decode, pb.n_segments):
                c = plan.chunks[i - pb.n_decode]
                st = sched.slots[c.slot]
                req = st.request
                if tr is not None:
                    tr.emit("prefill_chunk", t=t_emit, uid=req.uid,
                            slot=int(c.slot), start=int(c.start),
                            len=int(c.length))
                if st.needs_init:
                    st.needs_init = False
                    sched.release_cow_source(st)
                st.prefill_pos = c.start + c.length
                if not st.prefill_done:
                    continue
                # final chunk: its segment's logits seeded sampling
                plen = st.ctx_len
                sched.insert_prefix(st, (plen // page_size) * page_size)
                if st.is_resume:
                    tok[c.slot] = st.resume_pending
                    lens[c.slot] = plen
                    rem[c.slot] = st.resume_rem
                    act[c.slot] = True
                    st.last_token_at = now()
                    continue
                first = int(nxt[i])
                gen_budget = min(req.max_new_tokens, self.max_len - plen)
                if first != EOS and gen_budget > 0:
                    st.emitted.append(first)
                    record_emit(st, 1, now())
                if first == EOS or gen_budget <= 1:
                    retire(c.slot)
                else:
                    tok[c.slot] = first
                    lens[c.slot] = plen
                    rem[c.slot] = gen_budget - 1
                    act[c.slot] = True
            emit_iteration(budget_used=int(pb.n_tokens),
                           decode_lanes=len(plan.decode_slots),
                           chunk_segments=len(plan.chunks),
                           chunk_tokens=int(real), width_bucket=int(W),
                           padded_lanes=int(W - pb.n_tokens), idle=False)

        while incoming or sched.has_work():
            if tr is not None:
                it_acc["t0"] = now()
            # -- release arrived requests into the FCFS queue -------------
            while incoming and incoming[0][0] <= now():
                _, req = incoming.pop(0)
                if self.prune_maps is not None:
                    req.tokens = [int(t) for t in PR.remap_tokens(
                        np.asarray([req.tokens], np.int32),
                        self.prune_maps)[0]]
                if faults is not None and req.uid in faults.oversize_uids:
                    # inflate past the whole pool: the truncate-or-reject
                    # machinery below must absorb it, never raise
                    target = max(self.max_len,
                                 num_pages * page_size) + page_size
                    req.tokens = (req.tokens
                                  * (target // max(len(req.tokens), 1)
                                     + 1))[:target]
                if req.prompt_len > self.max_len:
                    # must cut: leave the truncated prompt room to
                    # actually generate (reserve its token budget, but
                    # keep at least half the context for the prompt)
                    limit = max(self.max_len - req.max_new_tokens,
                                self.max_len // 2)
                    req.tokens = truncate_prompt(req.tokens, limit,
                                                 uid=req.uid)
                    req.truncated = True
                sched.submit(req, now())

            # -- backpressure: cancel expired / unservable queued work ----
            for req in sched.cancel_expired(now()):
                count_outcome(req)

            # -- fault injection: pool-exhaustion squatter ----------------
            if faults is not None and faults.hold_pages and not fault_hold \
                    and metrics.admitted >= faults.hold_after_admits:
                fault_hold = sched.allocator.alloc(
                    min(faults.hold_pages,
                        sched.allocator.free_count)) or []

            # -- admit into free slots ------------------------------------
            if chunked:
                # unified scheduler: admission only CLAIMS a slot and its
                # pages; the prompt is prefilled in budgeted chunks by
                # the mixed iterations below, interleaved with decode
                while True:
                    adm = sched.try_admit(now())
                    if adm is not None:
                        slot, st = adm
                        block_tables[slot, :] = -1
                        block_tables[slot, :len(st.pages)] = st.pages
                        if st.restore_blob is not None:
                            # host-tier resume: scatter the snapshot back
                            # and rejoin decode exactly where it stopped
                            cache = KV.restore_pages(cache,
                                                     st.restore_blob,
                                                     st.pages)
                            st.restore_blob = None
                            metrics.restored_pages += len(st.pages)
                            metrics.resumed += 1
                            tok[slot] = st.resume_pending
                            lens[slot] = st.ctx_len
                            rem[slot] = st.resume_rem
                            act[slot] = True
                            st.last_token_at = now()
                            if tr is not None:
                                tr.emit_now("restore", uid=st.request.uid,
                                            slot=int(slot), mode="hostkv",
                                            n_pages=len(st.pages))
                        elif st.is_resume:
                            # host tier was full: re-prefill the context
                            # as ordinary chunks (recompute-resume)
                            metrics.resumed += 1
                            if tr is not None:
                                tr.emit_now("restore", uid=st.request.uid,
                                            slot=int(slot),
                                            mode="recompute",
                                            n_pages=len(st.pages))
                        else:
                            stats.prompt_tokens += st.request.prompt_len
                            metrics.admitted += 1
                            metrics.prefix_hits += st.matched_len > 0
                            metrics.prefix_matched_tokens += st.matched_len
                            metrics.pages_shared += st.shared_count
                            if tr is not None and st.matched_len > 0:
                                tr.emit_now(
                                    "prefix_hit", uid=st.request.uid,
                                    matched_tokens=int(st.matched_len),
                                    pages_shared=int(st.shared_count))
                        continue
                    # admission failed: preempt a decoding victim for the
                    # blocked head — only when a slot is FREE (pure pool
                    # pressure) and the head could actually fit after
                    # evicting every eligible victim (else preemption is
                    # churn that can never admit it)
                    if preemption == "off" or not sched.waiting \
                            or not sched.free_slots():
                        break
                    head = sched.waiting[0]
                    if sched.queued_pages_needed(head) \
                            > sched.preemptible_headroom(head):
                        break
                    victim = sched.pick_victim(head)
                    if victim is None:
                        break
                    n_pages = len(sched.slots[victim].pages)
                    vic_uid = sched.slots[victim].request.uid
                    _, offloaded = sched.preempt(
                        victim, pending=int(tok[victim]),
                        ctx_len=int(lens[victim]),
                        rem_tokens=int(rem[victim]))
                    act[victim] = False
                    block_tables[victim, :] = -1
                    metrics.preemptions += 1
                    if offloaded:
                        metrics.offloaded_pages += n_pages
                    if tr is not None:
                        tr.emit_now("preempt", uid=int(vic_uid),
                                    slot=int(victim), policy=preemption,
                                    n_pages=int(n_pages),
                                    offloaded=bool(offloaded))
                        if offloaded:
                            tr.emit_now("offload", uid=int(vic_uid),
                                        slot=int(victim),
                                        n_pages=int(n_pages))
                metrics.peak_pages_in_use = max(
                    metrics.peak_pages_in_use,
                    sched.allocator.allocated_count)
            # bucketed fallback: consecutive FCFS admissions sharing a
            # prompt bucket run as ONE batched whole-prompt prefill
            # dispatch (per-request prefills would serialize 1-row model
            # calls against the decode loop)
            pending_adm: List[tuple] = []      # [(slot, SlotState, bucket)]

            def flush_admissions():
                # power-of-two admission chunks: group size would otherwise
                # depend on scheduling timing, making the set of traced
                # (B, bucket) prefill shapes unbounded/nondeterministic
                while pending_adm:
                    B = 1 << (len(pending_adm).bit_length() - 1)
                    _flush_chunk([pending_adm.pop(0) for _ in range(B)])

            def _flush_chunk(chunk):
                nonlocal cache, rng
                bucket = chunk[0][2]
                B = len(chunk)
                toks = np.zeros((B, bucket), np.int32)
                plens = np.zeros((B,), np.int32)     # computed suffix lens
                starts = np.zeros((B,), np.int32)    # = matched prefix lens
                slots_arr = np.zeros((B,), np.int32)
                rows = np.zeros((B, pages_per_slot), np.int32)
                pages_arr = np.full((B, pages_per_slot), dump, np.int32)
                cow_src = np.full((B,), dump, np.int32)
                cow_dst = np.full((B,), dump, np.int32)
                cow_keep = np.zeros((B,), np.int32)
                for i, (slot, st, _) in enumerate(chunk):
                    req = st.request
                    m = st.matched_len
                    plens[i] = req.prompt_len - m
                    starts[i] = m
                    toks[i, :req.prompt_len - m] = req.tokens[m:]
                    slots_arr[i] = slot
                    block_tables[slot, :] = -1
                    block_tables[slot, :len(st.pages)] = st.pages
                    rows[i] = block_tables[slot]
                    # only the request's OWN pages are reset: shared prefix
                    # pages are live for other readers and the trie
                    pages_arr[i, :len(st.fresh_pages)] = st.fresh_pages
                    if st.cow_src >= 0:
                        # COW invariant: the destination must be private
                        if sched.allocator.refcount(st.fresh_pages[0]) != 1:
                            raise AssertionError(
                                "COW write target is a shared page")
                        cow_src[i] = st.cow_src
                        cow_dst[i] = st.fresh_pages[0]
                        cow_keep[i] = m
                        metrics.cow_copies += 1
                with dev_span("admit_prefill", "prefill"):
                    if share:
                        first, cache, rng = admit_prefix_fn(
                            self.params, jnp.asarray(toks),
                            jnp.asarray(plens),
                            jnp.asarray(starts), jnp.asarray(slots_arr),
                            jnp.asarray(rows), jnp.asarray(pages_arr),
                            jnp.asarray(cow_src), jnp.asarray(cow_dst),
                            jnp.asarray(cow_keep), cache, rng)
                    else:
                        first, cache, rng = admit_fn(
                            self.params, jnp.asarray(toks),
                            jnp.asarray(plens),
                            jnp.asarray(slots_arr), jnp.asarray(rows),
                            jnp.asarray(pages_arr), cache, rng)
                    first = np.asarray(jax.block_until_ready(first))
                metrics.host_syncs += 1
                t_adm = now()
                for i, (slot, st, _) in enumerate(chunk):
                    req = st.request
                    plen = req.prompt_len
                    sched.release_cow_source(st)
                    st.needs_init = False
                    st.prefill_pos = plen        # whole prompt in one go
                    stats.prompt_tokens += plen
                    metrics.admitted += 1
                    metrics.prefill_tokens += plen - st.matched_len
                    metrics.prefill_padded += bucket
                    metrics.prefix_hits += st.matched_len > 0
                    metrics.prefix_matched_tokens += st.matched_len
                    metrics.pages_shared += st.shared_count
                    if tr is not None:
                        tr.emit("prefill_chunk", t=t_adm, uid=req.uid,
                                slot=int(slot), start=int(st.matched_len),
                                len=int(plen - st.matched_len))
                        if st.matched_len > 0:
                            tr.emit("prefix_hit", t=t_adm, uid=req.uid,
                                    matched_tokens=int(st.matched_len),
                                    pages_shared=int(st.shared_count))
                    # newly produced page-aligned prompt KV joins the trie
                    # now (the partial tail joins at retire, once decode
                    # can no longer write into it)
                    sched.insert_prefix(st, (plen // page_size) * page_size)
                    budget = min(req.max_new_tokens, self.max_len - plen)
                    if first[i] != EOS and budget > 0:
                        st.emitted.append(int(first[i]))
                        record_emit(st, 1, t_adm)
                    if first[i] == EOS or budget <= 1:
                        retire(slot)
                    else:
                        tok[slot] = first[i]
                        lens[slot] = plen
                        rem[slot] = budget - 1
                        act[slot] = True

            while not chunked:         # flush may retire (budget 0/1, EOS
                progress = False       # at admit) and free slots: retry
                while True:
                    adm = sched.try_admit(now())
                    if adm is None:
                        break
                    progress = True
                    slot, st = adm
                    # only the unmatched suffix is computed; bucket on it
                    suffix = st.request.prompt_len - st.matched_len
                    bucket = suffix if pad_sensitive \
                        else pick_bucket(suffix, buckets)
                    if pending_adm and pending_adm[0][2] != bucket:
                        flush_admissions()
                    pending_adm.append((slot, st, bucket))
                flush_admissions()
                metrics.peak_pages_in_use = max(
                    metrics.peak_pages_in_use,
                    sched.allocator.allocated_count)
                if not progress or not sched.waiting:
                    break

            if sched.waiting and sched.free_slots() and sched.slots:
                # a slot sits idle because the pool can't hold the head
                # request's pages — the capacity ceiling int8 KV raises
                metrics.admission_stalls += 1

            if debug_audit:
                # fault-injection harness: refcount + host accounting
                # invariants must hold on EVERY iteration, not just at
                # the end of the run
                sched.allocator.check()
                if host is not None:
                    host.check()

            if not sched.slots:
                if sched.waiting:
                    # head request can never fit (no slot is live and
                    # eviction already reclaimed every unpinned cached
                    # page): fail it with a structured outcome rather
                    # than spin forever, and keep serving the rest
                    head = sched.waiting[0]
                    detail = (f"needs {sched.queued_pages_needed(head)} "
                              f"pages but the pool holds "
                              f"{sched.allocator.num_pages} "
                              f"({sched.allocator.free_count} free after "
                              f"eviction)")
                    warnings.warn(
                        f"request {head.uid}: {detail}; rejecting")
                    req = sched.fail_head(detail)
                    count_outcome(req)
                    emit_iteration(budget_used=0, decode_lanes=0,
                                   chunk_segments=0, chunk_tokens=0,
                                   width_bucket=0, padded_lanes=0,
                                   idle=True)
                    continue
                if incoming:        # idle until the next arrival
                    time.sleep(max(0.0, min(incoming[0][0] - now(), 0.01)))
                emit_iteration(budget_used=0, decode_lanes=0,
                               chunk_segments=0, chunk_tokens=0,
                               width_bucket=0, padded_lanes=0, idle=True)
                continue

            # -- unified token-budget iteration ----------------------------
            # any admitting slot -> one mixed iteration: every decoding
            # slot advances one token (single fused dispatch), then the
            # FCFS prefill chunks run packed (budget-bounded compute).
            # Pure-decode iterations fall through to the fused
            # steps_per_sync scan below.
            if chunked:
                plan = sched.next_batch(budget)
                if plan.chunks:
                    if packed_on:
                        # token-packed ragged: the WHOLE iteration is
                        # one (1, T) dispatch (accounted inside)
                        run_packed(plan)
                        continue
                    metrics.mixed_iters += 1
                    metrics.mixed_dispatches += len(plan.chunks)
                    if plan.decode_slots:
                        metrics.mixed_dispatches += 1
                    padded = run_mixed(plan)
                    emit_iteration(
                        budget_used=int(plan.total_tokens),
                        decode_lanes=len(plan.decode_slots),
                        chunk_segments=len(plan.chunks),
                        chunk_tokens=int(sum(c.length
                                             for c in plan.chunks)),
                        width_bucket=0, padded_lanes=int(padded),
                        idle=False)
                    continue

            # -- fused decode steps ---------------------------------------
            n_lanes = int(act.sum())   # lanes entering this dispatch
            if spec_on:
                # draft (host) -> one batched verify forward -> accept
                # the longest valid prefix per slot -> rewind rejected
                # KV.  One host sync per verify window.  Host-side
                # drafting stays inside the span, exactly like the
                # inline timer it replaced.
                with dev_span("verify", "decode"):
                    contexts: List[Optional[list]] = [None] * slots
                    for slot, st in sched.slots.items():
                        if act[slot]:
                            contexts[slot] = st.request.tokens + st.emitted
                    drafts = drafter.propose_slots(contexts)
                    (tok_d, lens_d, rem_d, act_d, cache, rng, emits,
                     accepted) = verify_fn(
                        self.params, jnp.asarray(tok), jnp.asarray(lens),
                        jnp.asarray(rem), jnp.asarray(act),
                        jnp.asarray(drafts), jnp.asarray(block_tables),
                        cache, rng)
                    emits = np.asarray(jax.block_until_ready(emits))
                metrics.host_syncs += 1
                metrics.steps += 1
                metrics.slot_steps_total += slots
                metrics.slot_steps_active += n_lanes
                metrics.drafted_tokens += drafter.k * n_lanes
                metrics.accepted_tokens += int(np.asarray(accepted).sum())
                budget_used = n_lanes * (drafter.k + 1)
            else:
                with dev_span("decode", "decode"):
                    (tok_d, lens_d, rem_d, act_d, cache, rng, emits,
                     acts) = step_fn(self.params, jnp.asarray(tok),
                                     jnp.asarray(lens), jnp.asarray(rem),
                                     jnp.asarray(act),
                                     jnp.asarray(block_tables), cache,
                                     rng)
                    emits = np.asarray(jax.block_until_ready(emits))
                metrics.host_syncs += 1
                acts = np.asarray(acts)
                metrics.steps += steps_per_sync
                metrics.slot_steps_total += slots * steps_per_sync
                metrics.slot_steps_active += int(acts.sum())
                budget_used = n_lanes * steps_per_sync
            apply_decode_results(tok_d, lens_d, rem_d, act_d, emits)
            emit_iteration(budget_used=int(budget_used),
                           decode_lanes=n_lanes, chunk_segments=0,
                           chunk_tokens=0, width_bucket=0, padded_lanes=0,
                           idle=False)

        # host/device wall-time split for the whole run: device_s is the
        # time spent inside (blocking) device dispatches, host_s is
        # everything else — scheduling, packing, bookkeeping, idling for
        # arrivals.  Mid-prompt chunk dispatches are async, so their
        # device time books against whichever later dispatch blocks.
        metrics.device_s = stats.prefill_s + stats.decode_s
        metrics.host_s = max(0.0, now() - metrics.device_s)
        self.rng = rng
        ctx["cache"] = cache           # pool persists across serve calls
        if fault_hold:                 # release the injected squatter
            sched.allocator.free(fault_hold)
        metrics.prefix_evicted_pages = trie.evicted_pages - trie_base
        # trie spills count as offloads, promotions as restores; the trie
        # outlives this call but its device closure must not (the next
        # serve rebinds a fresh cache) — spills pause between calls
        metrics.offloaded_pages += trie.spilled_pages - spill_base
        metrics.restored_pages += sched.promoted_pages - promote_base
        trie.offload_fn = None
        trie.trace = None
        if host is not None:
            host.trace = None
            host.check()
            metrics.host_bytes_used = host.used_bytes
            metrics.host_bytes_peak = host.peak_bytes
        if self.prune_maps is not None:
            for r in requests:
                if r.result:
                    r.result = [int(t) for t in PR.unmap_tokens(
                        np.asarray([r.result]), self.prune_maps)[0]]
        stats.generated_tokens = metrics.generated_tokens
        self.stats.merge(stats)
        # pool accounting must balance: every page is free or cached, and
        # nothing a retired request held leaked (alloc == free + resident)
        sched.allocator.check()
        resident = trie.resident_pages
        if sorted(set(resident)) != sorted(
                p for p in range(num_pages)
                if sched.allocator.refcount(p) > 0) \
                or any(sched.allocator.refcount(p) != 1 for p in resident):
            raise AssertionError("page leak: allocated pages != pages "
                                 "resident in the prefix cache")
        return requests, metrics

    # -- request-level API (P4 dynamic batching) -------------------------
    def serve(self, requests: List[Request],
              sp: SamplingParams = SamplingParams()) -> List[Request]:
        batcher = DynamicBatcher(max_batch=self.max_batch,
                                 buckets=self.prompt_buckets())
        for r in requests:
            batcher.add(r)
        while True:
            batch = batcher.next_batch()
            if batch is None:
                break
            toks, lens = pad_batch(batch)
            max_new = max(r.max_new_tokens for r in batch.requests)
            gen = self.generate_batch(toks, lens, max_new, sp)
            for i, r in enumerate(batch.requests):
                row = gen[i]
                r.result = [int(t) for t in row[row >= 0]][:r.max_new_tokens]
        return requests
