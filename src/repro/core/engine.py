"""Batched inference engine — where the paper's pillars compose.

  * P1: KV-cache prefill/decode split, half-precision policy, buffer
    donation (decode updates the cache in place = Paddle "memory reuse").
  * P2: optionally runs a pruned model with id remapping at the boundary.
  * P4: dynamic length-bucketed batching via :class:`DynamicBatcher`.

Also provides the *baseline* path (``use_kv_cache=False``) that re-runs the
full forward for every generated token — the paper's Table-1 row 1 — so the
speedup of the optimized stack is measurable against it.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import pruning as PR
from repro.core.precision import BF16, Policy
from repro.core.sampling import SamplingParams, sample
from repro.core.scheduler import Batch, DynamicBatcher, Request, pad_batch
from repro.core.tokenizer import EOS
from repro.models import transformer as T


@dataclass
class EngineStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    nocache_s: float = 0.0
    prompt_tokens: int = 0
    generated_tokens: int = 0
    batches: int = 0

    def merge(self, other: "EngineStats"):
        for f in self.__dataclass_fields__:
            setattr(self, f, getattr(self, f) + getattr(other, f))


class InferenceEngine:
    """Single-host serving engine for one model (single-stream vocab).

    Multi-codebook (audio) models are served through ``launch/serve.py``'s
    serve_step directly; this engine covers the text path the paper targets.
    """

    def __init__(self, cfg: ModelConfig, params, *, policy: Policy = BF16,
                 max_batch: int = 8, max_len: int = 512,
                 use_kv_cache: bool = True, donate: bool = True,
                 prune_maps: Optional[PR.PruneMaps] = None, seed: int = 0):
        self.cfg = cfg
        self.policy = policy
        self.params = policy.cast_params(params)
        self.max_batch = max_batch
        self.max_len = max_len
        self.use_kv_cache = use_kv_cache
        self.prune_maps = prune_maps
        self.rng = jax.random.PRNGKey(seed)
        self.stats = EngineStats()

        def prefill_fn(params, tokens, lengths, cache, start=0):
            return T.forward_prefill(params, cfg, tokens, lengths, cache,
                                     policy=policy, max_len=max_len,
                                     start=start)

        def decode_fn(params, tokens, cache, lengths):
            return T.forward_decode(params, cfg, tokens, cache, lengths,
                                    policy=policy, max_len=max_len)

        def full_fn(params, tokens):
            return T.forward_train(params, cfg, tokens, policy=policy,
                                   remat=False)[0]

        def decode_n_fn(params, first_tok, cache, lengths, n_steps):
            """Fused greedy decode loop (beyond-paper): one compiled
            lax.scan instead of n host dispatches — removes per-token
            launch overhead, keeps the cache update in place."""

            def body(carry, _):
                tok, cache, lens, done = carry
                logits, cache = T.forward_decode(params, cfg, tok[:, None],
                                                 cache, lens, policy=policy,
                                                 max_len=max_len)
                nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                nxt = jnp.where(done, 0, nxt)
                done = done | (nxt == EOS)     # EOS itself is not emitted
                emit = jnp.where(done, -1, nxt)
                return (nxt, cache, lens + 1, done), emit

            B = first_tok.shape[0]
            done0 = first_tok == EOS
            carry = (jnp.where(done0, 0, first_tok), cache, lengths, done0)
            carry, emitted = jax.lax.scan(body, carry, None, length=n_steps)
            return emitted.T, carry[1]                    # (B, n), cache

        dn = (3,) if donate else ()
        self._prefill = jax.jit(prefill_fn, donate_argnums=dn,
                                static_argnums=(4,))
        self._prefix_cache = None
        self._prefix_len = 0
        self._decode = jax.jit(decode_fn,
                               donate_argnums=(2,) if donate else ())
        self._decode_n = jax.jit(decode_n_fn, static_argnums=(4,),
                                 donate_argnums=(2,) if donate else ())
        self._full = jax.jit(full_fn)

    # ------------------------------------------------------------------
    def generate_batch(self, tokens: np.ndarray, lengths: np.ndarray,
                       max_new_tokens: int,
                       sp: SamplingParams = SamplingParams(),
                       stop_at_eos: bool = True) -> np.ndarray:
        """tokens: (B, L) right-padded int32. Returns (B, max_new) ids
        (PAD-filled after EOS)."""
        if self.prune_maps is not None:
            tokens = PR.remap_tokens(tokens, self.prune_maps)
        if self.use_kv_cache:
            out = self._generate_kv(tokens, lengths, max_new_tokens, sp,
                                    stop_at_eos)
        else:
            out = self._generate_nocache(tokens, lengths, max_new_tokens, sp,
                                         stop_at_eos)
        if self.prune_maps is not None:
            out = PR.unmap_tokens(np.maximum(out, 0), self.prune_maps) \
                * (out >= 0) + out * (out < 0)
        return out

    # -- prefix caching (paper §1: "extracted relevant content offline") --
    def set_prefix(self, prefix_tokens) -> None:
        """Precompute the KV/state cache of a shared prompt prefix once;
        every subsequent request reuses it (broadcast across slots)."""
        toks = jnp.asarray(prefix_tokens, jnp.int32)[None]
        cache = T.init_cache(self.cfg, 1, self.max_len,
                             self.policy.compute_dtype)
        _, cache = self._prefill(self.params, toks,
                                 jnp.asarray([toks.shape[1]], jnp.int32),
                                 cache, 0)
        self._prefix_cache = cache
        self._prefix_len = int(toks.shape[1])

    def clear_prefix(self) -> None:
        self._prefix_cache = None
        self._prefix_len = 0

    def _fresh_cache(self, B):
        if self._prefix_cache is None:
            return T.init_cache(self.cfg, B, self.max_len,
                                self.policy.compute_dtype), 0
        # broadcast the single-slot prefix cache to B slots
        cache = jax.tree.map(
            lambda a: jnp.repeat(a, B, axis=1), self._prefix_cache)
        return cache, self._prefix_len

    # -- optimized path (P1) --------------------------------------------
    def _generate_kv(self, tokens, lengths, max_new, sp, stop_at_eos):
        B = tokens.shape[0]
        cache, start = self._fresh_cache(B)
        t0 = time.perf_counter()
        toks = jnp.asarray(tokens, jnp.int32)
        lens = jnp.asarray(lengths, jnp.int32) + start
        logits, cache = self._prefill(self.params, toks,
                                      jnp.asarray(lengths, jnp.int32),
                                      cache, start)
        logits = jax.block_until_ready(logits)
        t1 = time.perf_counter()

        out = np.full((B, max_new), -1, np.int64)
        # logits cover the suffix only; last real token is suffix-local
        last = logits[jnp.arange(B), jnp.asarray(lengths, jnp.int32) - 1]
        self.rng, sub = jax.random.split(self.rng)
        first = sample(last, sub, sp)

        if sp.temperature <= 0.0 and max_new > 1 and stop_at_eos:
            # fused greedy loop: a single compiled scan over the steps;
            # `first` sits at absolute position `lens`
            first_np = np.asarray(first)
            out[:, 0] = np.where(first_np == EOS, -1, first_np)
            emitted, cache = self._decode_n(self.params, first, cache,
                                            lens, max_new - 1)
            out[:, 1:] = np.asarray(emitted)
        else:
            done = np.zeros((B,), bool)
            nxt = first
            for step in range(max_new):
                nxt_np = np.asarray(nxt)
                if stop_at_eos:
                    done |= nxt_np == EOS
                out[~done, step] = nxt_np[~done]
                if done.all() or step == max_new - 1:
                    break
                logits1, cache = self._decode(self.params, nxt[:, None],
                                              cache, lens + step)
                self.rng, sub = jax.random.split(self.rng)
                nxt = sample(logits1[:, 0], sub, sp)
        jax.block_until_ready(cache["layers"])
        t2 = time.perf_counter()
        self.stats.merge(EngineStats(
            prefill_s=t1 - t0, decode_s=t2 - t1,
            prompt_tokens=int(lengths.sum()),
            generated_tokens=int((out >= 0).sum()), batches=1))
        return out

    # -- paper Table-1 baseline: no KV cache ------------------------------
    def _generate_nocache(self, tokens, lengths, max_new, sp, stop_at_eos):
        B, L = tokens.shape
        total = L + max_new
        buf = np.zeros((B, total), np.int32)
        buf[:, :L] = tokens
        lens = np.asarray(lengths).copy()
        out = np.full((B, max_new), -1, np.int64)
        done = np.zeros((B,), bool)
        t0 = time.perf_counter()
        for step in range(max_new):
            logits = self._full(self.params, jnp.asarray(buf))
            last = logits[jnp.arange(B), jnp.asarray(lens - 1)]
            self.rng, sub = jax.random.split(self.rng)
            nxt = np.asarray(sample(last, sub, sp))
            if stop_at_eos:
                done |= nxt == EOS
            out[~done, step] = nxt[~done]
            buf[np.arange(B), lens] = np.where(done, 0, nxt)
            lens = lens + (~done).astype(lens.dtype)
            if done.all():
                break
        t1 = time.perf_counter()
        self.stats.merge(EngineStats(
            nocache_s=t1 - t0, prompt_tokens=int(np.sum(lengths)),
            generated_tokens=int((out >= 0).sum()), batches=1))
        return out

    # -- request-level API (P4 dynamic batching) -------------------------
    def serve(self, requests: List[Request],
              sp: SamplingParams = SamplingParams()) -> List[Request]:
        batcher = DynamicBatcher(max_batch=self.max_batch)
        for r in requests:
            batcher.add(r)
        while True:
            batch = batcher.next_batch()
            if batch is None:
                break
            toks, lens = pad_batch(batch)
            max_new = max(r.max_new_tokens for r in batch.requests)
            gen = self.generate_batch(toks, lens, max_new, sp)
            for i, r in enumerate(batch.requests):
                row = gen[i]
                r.result = [int(t) for t in row[row >= 0]][:r.max_new_tokens]
        return requests
