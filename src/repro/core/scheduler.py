"""Dynamic batch scheduling — paper §2.3 ("Dynamic Batch Size") + the data
inference-order optimization from §1 ("optimized the allocation of data
inference order").

Requests are sorted by prompt length and grouped into batches whose padded
shapes come from a small set of length buckets, so (a) padding waste is
minimized (the paper's Figure-3 observation: real inputs are much shorter
than the model maximum) and (b) XLA recompilation is bounded to the bucket
set.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

DEFAULT_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048, 4096)


class PromptOverflowError(ValueError):
    """Prompt longer than the largest bucket with overflow='reject'."""


def truncate_prompt(tokens: List[int], limit: int, *,
                    uid: Optional[int] = None) -> List[int]:
    """Left-truncate an over-long prompt to its last ``limit`` tokens,
    warning loudly — the *recent* context is what conditions generation.
    (Replaces the old silent right-side clamp in ``pad_batch``.)"""
    if len(tokens) <= limit:
        return tokens
    who = f"request {uid}" if uid is not None else "request"
    warnings.warn(
        f"{who}: prompt of {len(tokens)} tokens exceeds the maximum "
        f"length {limit}; keeping the last {limit} tokens",
        stacklevel=2)
    return tokens[-limit:]


TERMINAL_STATUSES = ("completed", "truncated", "timed_out", "rejected")


@dataclass
class RequestOutcome:
    """Terminal disposition of a request on the continuous path: every
    submitted request ends in exactly one of ``TERMINAL_STATUSES`` —
    overload degrades outcomes, it never loses requests.

      completed  served to EOS / its token budget
      truncated  served, but the prompt was cut to fit the context
      timed_out  cancelled in the queue (deadline / max_queue_wait);
                 tokens generated before a preemption are preserved
      rejected   could never fit (pool smaller than the request)
    """
    status: str
    preemptions: int = 0               # times the request lost its slot
    deadline_missed: bool = False      # finished (or died) past deadline
    detail: str = ""


@dataclass
class Request:
    uid: int
    tokens: List[int]                  # prompt token ids
    max_new_tokens: int = 32
    result: Optional[List[int]] = None # filled by the engine
    # prompt tokens served zero-copy from the radix prefix cache (set at
    # continuous admission; 0 on the bucket path / when sharing is off)
    prefix_tokens_matched: int = 0
    # -- overload-survivable serving ----------------------------------------
    priority: int = 0                  # higher = preempts lower under
    #                                    the "priority" preemption policy
    deadline: Optional[float] = None   # absolute serve-clock seconds (same
    #                                    timeline as arrival offsets)
    max_queue_wait: Optional[float] = None  # seconds from submission
    truncated: bool = False            # prompt was cut to fit the context
    preemptions: int = 0               # slot evictions suffered so far
    outcome: Optional[RequestOutcome] = None  # set once, at a terminal point

    @property
    def prompt_len(self) -> int:
        return len(self.tokens)


@dataclass
class Batch:
    requests: List[Request]
    padded_len: int

    @property
    def size(self) -> int:
        return len(self.requests)


def pick_bucket(length: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if length <= b:
            return b
    return buckets[-1]


@dataclass
class DynamicBatcher:
    max_batch: int = 8
    buckets: Sequence[int] = DEFAULT_BUCKETS
    sort_by_length: bool = True        # the paper's inference-order trick
    overflow: str = "truncate"         # over-long prompts: truncate | reject
    _queue: List[Request] = field(default_factory=list)

    def add(self, req: Request) -> None:
        limit = self.buckets[-1]
        if req.prompt_len > limit:
            if self.overflow == "reject":
                raise PromptOverflowError(
                    f"request {req.uid}: prompt of {req.prompt_len} tokens "
                    f"exceeds the largest bucket ({limit})")
            req.tokens = truncate_prompt(req.tokens, limit, uid=req.uid)
        self._queue.append(req)

    def pending(self) -> int:
        return len(self._queue)

    def next_batch(self) -> Optional[Batch]:
        """Greedy: take up to max_batch requests sharing a length bucket."""
        if not self._queue:
            return None
        if self.sort_by_length:
            self._queue.sort(key=lambda r: r.prompt_len)
        head_bucket = pick_bucket(self._queue[0].prompt_len, self.buckets)
        take: List[Request] = []
        rest: List[Request] = []
        for r in self._queue:
            if (len(take) < self.max_batch
                    and pick_bucket(r.prompt_len, self.buckets)
                    == head_bucket):
                take.append(r)
            else:
                rest.append(r)
        self._queue = rest
        return Batch(requests=take, padded_len=head_bucket)


def pad_batch(batch: Batch, pad_id: int = 0):
    """-> (tokens (B, L) int32, lengths (B,) int32)."""
    B, L = batch.size, batch.padded_len
    toks = np.full((B, L), pad_id, np.int32)
    lens = np.zeros((B,), np.int32)
    for i, r in enumerate(batch.requests):
        if len(r.tokens) > L:
            # DynamicBatcher.add truncates on entry; a longer prompt here
            # means a hand-built Batch — fail loudly, never clip silently.
            raise PromptOverflowError(
                f"request {r.uid}: {len(r.tokens)} tokens > padded_len {L}")
        toks[i, :len(r.tokens)] = r.tokens
        lens[i] = len(r.tokens)
    return toks, lens
