"""Precision policy — paper pillar P1 (FP16 half-precision inference).

The paper runs FP16 inference on GPU.  On TPU the MXU-native half precision
is bf16, so the *default serving policy* here is bf16-compute; fp16 is kept
selectable for paper fidelity (and is what the Table-1 reproduction
benchmark uses).  A policy is three dtypes:

  * ``param_dtype``   — storage dtype of the weights
  * ``compute_dtype`` — dtype activations/matmuls run in
  * ``output_dtype``  — dtype of logits (kept fp32 for a stable softmax)
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    output_dtype: jnp.dtype = jnp.float32

    def cast_params(self, params):
        """Cast a parameter pytree to ``param_dtype`` (storage)."""
        return jax.tree.map(
            lambda p: p.astype(self.param_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)

    def compute_cast(self, tree):
        """Cast activations (or params at point-of-use) to compute dtype."""
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def output_cast(self, x):
        return x.astype(self.output_dtype)


FP32 = Policy()
BF16 = Policy(jnp.bfloat16, jnp.bfloat16, jnp.float32)          # TPU-native default
FP16 = Policy(jnp.float16, jnp.float16, jnp.float32)            # paper-faithful
MIXED_TRAIN = Policy(jnp.float32, jnp.bfloat16, jnp.float32)    # fp32 master weights


_POLICIES = {"fp32": FP32, "bf16": BF16, "fp16": FP16, "mixed": MIXED_TRAIN}


def get_policy(name: str) -> Policy:
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; one of {list(_POLICIES)}")
