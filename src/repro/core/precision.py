"""Precision policy — paper pillar P1 (FP16 half-precision inference).

The paper runs FP16 inference on GPU.  On TPU the MXU-native half precision
is bf16, so the *default serving policy* here is bf16-compute; fp16 is kept
selectable for paper fidelity (and is what the Table-1 reproduction
benchmark uses).  A policy is three dtypes:

  * ``param_dtype``   — storage dtype of the weights
  * ``compute_dtype`` — dtype activations/matmuls run in
  * ``output_dtype``  — dtype of logits (kept fp32 for a stable softmax)

plus one *storage* axis for the serving KV cache:

  * ``kv_dtype``      — "auto" (= compute dtype), "bf16", "fp16", or
    "int8".  int8 stores paged attention K/V pages as int8 with
    per-entry, per-kv-head fp32 absmax scales in parallel scale pools
    (see ``kv_cache``); it halves KV bytes/token vs bf16, doubling the
    effective page-pool capacity and the decode kernel's arithmetic
    intensity.  Layer families with dense per-slot state (MLA,
    recurrent, hybrid) keep full-precision caches — the same families
    that opt out of prefix sharing.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

KV_DTYPES = ("auto", "bf16", "fp16", "int8")


def kv_store_dtype(kv_dtype: str, compute_dtype, *, allow_int8: bool = True):
    """Resolve a ``Policy.kv_dtype`` name to the cache storage dtype.

    ``allow_int8=False`` is the dense-cache path (no scale arrays live
    beside a dense cache), where int8 falls back to the compute dtype.
    """
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                         f"one of {list(KV_DTYPES)}")
    if kv_dtype == "auto":
        return compute_dtype
    if kv_dtype == "bf16":
        return jnp.bfloat16
    if kv_dtype == "fp16":
        return jnp.float16
    return jnp.int8 if allow_int8 else compute_dtype


@dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    output_dtype: jnp.dtype = jnp.float32
    kv_dtype: str = "auto"

    def cast_params(self, params):
        """Cast a parameter pytree to ``param_dtype`` (storage)."""
        return jax.tree.map(
            lambda p: p.astype(self.param_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)

    def compute_cast(self, tree):
        """Cast activations (or params at point-of-use) to compute dtype."""
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def output_cast(self, x):
        return x.astype(self.output_dtype)

    def kv_cache_dtype(self, *, dense: bool = False):
        """Storage dtype for KV caches under this policy.  ``dense=True``
        (per-slot caches without scale pools) maps int8 back to the
        compute dtype — only the paged pool supports quantized storage."""
        return kv_store_dtype(self.kv_dtype, self.compute_dtype,
                              allow_int8=not dense)


FP32 = Policy()
BF16 = Policy(jnp.bfloat16, jnp.bfloat16, jnp.float32)          # TPU-native default
FP16 = Policy(jnp.float16, jnp.float16, jnp.float32)            # paper-faithful
MIXED_TRAIN = Policy(jnp.float32, jnp.bfloat16, jnp.float32)    # fp32 master weights


_POLICIES = {"fp32": FP32, "bf16": BF16, "fp16": FP16, "mixed": MIXED_TRAIN}


def get_policy(name: str) -> Policy:
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; one of {list(_POLICIES)}")
