"""Precision policy — paper pillar P1 (FP16 half-precision inference).

The paper runs FP16 inference on GPU.  On TPU the MXU-native half precision
is bf16, so the *default serving policy* here is bf16-compute; fp16 is kept
selectable for paper fidelity (and is what the Table-1 reproduction
benchmark uses).  A policy is three dtypes:

  * ``param_dtype``   — storage dtype of the weights
  * ``compute_dtype`` — dtype activations/matmuls run in
  * ``output_dtype``  — dtype of logits (kept fp32 for a stable softmax)

plus two *storage* axes for serving:

  * ``kv_dtype``      — "auto" (= compute dtype), "bf16", "fp16", or
    "int8".  int8 stores paged attention K/V pages as int8 with
    per-entry, per-kv-head fp32 absmax scales in parallel scale pools
    (see ``kv_cache``); it halves KV bytes/token vs bf16, doubling the
    effective page-pool capacity and the decode kernel's arithmetic
    intensity.  Layer families with dense per-slot state (MLA,
    recurrent, hybrid) keep full-precision caches — the same families
    that opt out of prefix sharing.
  * ``weights_dtype`` — storage of the dense serve-path matmul weights
    (attention qkv/out projections, dense FFNs, the unembedding head).
    "auto" keeps ``param_dtype``; "int8" quantizes each weight at load
    into int8 codes + per-output-channel fp32 absmax scales
    (:func:`quantize_weights`, the weight-matrix mirror of the KV-pool
    scheme), halving weight bytes read per decode step — the dominant
    traffic of autoregressive decode, where every matmul is
    weight-bound.  Matmuls against quantized records dequantize
    in-register (``kernels/quant_matmul``) or accumulate the int8
    codes in fp32 and apply the scale to the product (the exact
    per-column identity ``x @ (q*s) == (x @ q) * s``) on the jnp
    fallback.  Only structurally dense projections quantize: MLA
    low-rank factors, recurrent mixers, MoE expert stacks, norms and
    the embedding *gather* table keep full precision (tied-embedding
    models get a separate quantized copy of the unembed projection;
    the gather table itself is never quantized).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

KV_DTYPES = ("auto", "bf16", "fp16", "int8")
WEIGHTS_DTYPES = ("auto", "bf16", "fp16", "int8")

# Shared with the KV pool's scheme: symmetric absmax, full [-127, 127]
# code range (never -128, keeping |q| * s <= absmax exactly).
W8_QMAX = 127.0


def kv_store_dtype(kv_dtype: str, compute_dtype, *, allow_int8: bool = True):
    """Resolve a ``Policy.kv_dtype`` name to the cache storage dtype.

    ``allow_int8=False`` is the dense-cache path (no scale arrays live
    beside a dense cache), where int8 falls back to the compute dtype.
    """
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                         f"one of {list(KV_DTYPES)}")
    if kv_dtype == "auto":
        return compute_dtype
    if kv_dtype == "bf16":
        return jnp.bfloat16
    if kv_dtype == "fp16":
        return jnp.float16
    return jnp.int8 if allow_int8 else compute_dtype


def weights_store_dtype(weights_dtype: str, param_dtype):
    """Resolve a ``Policy.weights_dtype`` name to the weight storage dtype."""
    if weights_dtype not in WEIGHTS_DTYPES:
        raise ValueError(f"unknown weights_dtype {weights_dtype!r}; "
                         f"one of {list(WEIGHTS_DTYPES)}")
    if weights_dtype == "auto":
        return param_dtype
    if weights_dtype == "bf16":
        return jnp.bfloat16
    if weights_dtype == "fp16":
        return jnp.float16
    return jnp.int8


# ---------------------------------------------------------------------------
# Weight-only int8 quantization (per-output-channel absmax)
# ---------------------------------------------------------------------------


def quantize_weights(w):
    """Quantize one dense weight ``w`` (..., in, out) to an int8 record.

    Returns ``{"q": int8 (..., in, out), "s": fp32 (..., out)}`` with
    per-output-channel absmax scales (``s = absmax / 127`` over the
    input dim).  Per-*column* scales make the dequantized matmul an
    exact rescale of the integer product — ``x @ (q * s) == (x @ q) * s``
    column by column — so the fused kernel and the jnp fallback can both
    accumulate codes in fp32 and apply the scale once per output.
    All-zero columns get scale 0 (codes 0) via the epsilon guard, the
    same convention as ``kv_cache.quantize_kv``.
    """
    wf = jnp.asarray(w).astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2)
    scale = amax / W8_QMAX
    q = jnp.clip(jnp.round(wf / jnp.maximum(scale, 1e-30)[..., None, :]),
                 -W8_QMAX, W8_QMAX).astype(jnp.int8)
    return {"q": q, "s": scale}


def dequantize_weights(rec, dtype=jnp.float32):
    """Inverse of :func:`quantize_weights` (up to the rounding error)."""
    return (rec["q"].astype(jnp.float32)
            * rec["s"][..., None, :]).astype(dtype)


def is_quantized_weight(w) -> bool:
    """True for the ``{"q", "s"}`` records :func:`quantize_weights` makes."""
    return isinstance(w, dict) and set(w) == {"q", "s"}


def _array_bytes(a) -> int:
    return int(a.size) * jnp.dtype(a.dtype).itemsize


def weight_record_bytes(w) -> int:
    """Storage bytes of one serve-path weight (array or quantized record)."""
    if is_quantized_weight(w):
        return _array_bytes(w["q"]) + _array_bytes(w["s"])
    return _array_bytes(w)


# Dense serve-path matmul weights, identified structurally: a GQA
# attention dict carries all four projections (mLSTM has wq/wk/wv but no
# wo; MLA factors use different names), a dense FFN dict carries wi+wo
# without a router (MoE expert stacks are excluded by their router key;
# MoE *shared* experts are a plain dense FFN dict and do quantize).
_ATTN_KEYS = ("wq", "wk", "wv", "wo")
_FFN_KEYS = ("wi", "wg", "wo")


def _walk_serve_weights(node):
    """Yield (dict, key) for every dense serve-path matmul weight."""
    if not isinstance(node, dict):
        if isinstance(node, (tuple, list)):
            for v in node:
                yield from _walk_serve_weights(v)
        return
    if all(k in node for k in _ATTN_KEYS):
        for k in _ATTN_KEYS:
            yield node, k
    elif "wi" in node and "wo" in node and "router" not in node:
        for k in _FFN_KEYS:
            if k in node:
                yield node, k
    for v in node.values():
        yield from _walk_serve_weights(v)


def compress_weights(params, policy: "Policy"):
    """Apply ``policy.weights_dtype`` to the dense serve-path weights.

    Returns ``(params, stats)``.  For "int8", each weight is replaced
    in-place (a copied tree) by its :func:`quantize_weights` record; the
    unembedding head quantizes too — directly for untied models, and as
    a *separate* ``embed["head_q8"]`` copy of the transposed gather
    table for tied models (the gather table itself stays full precision
    for exact embedding lookups; the int8 copy costs a quarter of the
    fp32 table but halves the bytes the unembed matmul reads).  "bf16"/
    "fp16" cast the same weight set; "auto" is a no-op.

    ``stats`` reports the serve-path matmul read traffic:
    ``weight_bytes`` (bytes those matmuls read after compression),
    ``weight_bytes_dense`` (same set before), ``weight_bytes_saved``,
    ``n_quantized``, and the resolved ``weights_dtype`` name.  Call
    AFTER :meth:`Policy.cast_params` — cast_params would recast the
    fp32 scales of an already-quantized tree.
    """
    wd = policy.weights_dtype
    if wd not in WEIGHTS_DTYPES:
        raise ValueError(f"unknown weights_dtype {wd!r}; "
                         f"one of {list(WEIGHTS_DTYPES)}")
    items = list(_walk_serve_weights(params))

    # the unembed projection, as the serve-path matmul reads it
    embed = params.get("embed", {}) if isinstance(params, dict) else {}
    head = embed.get("head")
    tied_tokens = None
    if head is None and "tokens" in embed and "heads" not in embed \
            and getattr(embed["tokens"], "ndim", 0) == 2:
        tied_tokens = embed["tokens"]          # tied single-stream vocab

    dense_bytes = sum(weight_record_bytes(d[k]) for d, k in items)
    if head is not None:
        dense_bytes += weight_record_bytes(head)
    elif tied_tokens is not None:
        dense_bytes += weight_record_bytes(tied_tokens)

    if wd == "auto" or not (items or head is not None
                            or tied_tokens is not None):
        return params, {"weights_dtype": wd, "weight_bytes": dense_bytes,
                        "weight_bytes_dense": dense_bytes,
                        "weight_bytes_saved": 0, "n_quantized": 0}

    if wd == "int8":
        transform = quantize_weights
    else:
        store = weights_store_dtype(wd, policy.param_dtype)
        transform = lambda w: w.astype(store)

    # copy every container so the caller's tree is never mutated, then
    # transform the serve-path weights in place on the fresh containers
    def copy_tree(node):
        if isinstance(node, dict):
            return {k: copy_tree(v) for k, v in node.items()}
        if isinstance(node, tuple):
            return tuple(copy_tree(v) for v in node)
        if isinstance(node, list):
            return [copy_tree(v) for v in node]
        return node

    new_params = copy_tree(params)
    n_q = 0
    for d, k in _walk_serve_weights(new_params):
        d[k] = transform(d[k])
        n_q += 1
    new_embed = new_params.get("embed")
    if head is not None:
        new_embed["head"] = transform(head)
        n_q += 1
    elif tied_tokens is not None and wd == "int8":
        # tied models: quantize the TRANSPOSED table (d, V) so unembed
        # reads an int8 (in, out) record like every other projection
        new_embed["head_q8"] = quantize_weights(
            tied_tokens.astype(jnp.float32).T)
        n_q += 1

    comp_items = list(_walk_serve_weights(new_params))
    comp_bytes = sum(weight_record_bytes(d[k]) for d, k in comp_items)
    if head is not None:
        comp_bytes += weight_record_bytes(new_embed["head"])
    elif tied_tokens is not None:
        if wd == "int8":
            comp_bytes += weight_record_bytes(new_embed["head_q8"])
        else:
            comp_bytes += weight_record_bytes(tied_tokens)
    return new_params, {"weights_dtype": wd, "weight_bytes": comp_bytes,
                        "weight_bytes_dense": dense_bytes,
                        "weight_bytes_saved": dense_bytes - comp_bytes,
                        "n_quantized": n_q}


@dataclass(frozen=True)
class Policy:
    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.float32
    output_dtype: jnp.dtype = jnp.float32
    kv_dtype: str = "auto"
    weights_dtype: str = "auto"

    def cast_params(self, params):
        """Cast a parameter pytree to ``param_dtype`` (storage)."""
        return jax.tree.map(
            lambda p: p.astype(self.param_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params)

    def compute_cast(self, tree):
        """Cast activations (or params at point-of-use) to compute dtype."""
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)

    def output_cast(self, x):
        return x.astype(self.output_dtype)

    def kv_cache_dtype(self, *, dense: bool = False):
        """Storage dtype for KV caches under this policy.  ``dense=True``
        (per-slot caches without scale pools) maps int8 back to the
        compute dtype — only the paged pool supports quantized storage."""
        return kv_store_dtype(self.kv_dtype, self.compute_dtype,
                              allow_int8=not dense)


FP32 = Policy()
BF16 = Policy(jnp.bfloat16, jnp.bfloat16, jnp.float32)          # TPU-native default
FP16 = Policy(jnp.float16, jnp.float16, jnp.float32)            # paper-faithful
MIXED_TRAIN = Policy(jnp.float32, jnp.bfloat16, jnp.float32)    # fp32 master weights


_POLICIES = {"fp32": FP32, "bf16": BF16, "fp16": FP16, "mixed": MIXED_TRAIN}


def get_policy(name: str) -> Policy:
    try:
        return _POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; one of {list(_POLICIES)}")
