"""Continuous (in-flight) batching — the serving-side successor of the
paper's §2.3 dynamic batching.

The bucket batcher (`DynamicBatcher`) drains whole batches: every request
decodes until the *longest* one finishes, and each batch allocates a fresh
dense cache.  Here, a fixed set of decode *slots* runs forever; requests
are admitted into free slots mid-flight and retired at EOS, so the decode
step is always as full as the traffic allows.  KV memory is a shared pool
of fixed-size pages (see ``kv_cache.PAGED_KEYS``): pages are refcounted —
allocated on admit, released on retire, and *shared* across requests with
a common prompt prefix through :class:`~repro.core.prefix_cache.
RadixPrefixCache` (a shared page is never written; copy-on-write hands
the writer a fresh copy of a partial tail page).

Scheduling is a **unified token-budget iteration** (chunked prefill):
each step, :meth:`ContinuousScheduler.next_batch` packs one decode token
per live slot plus up to the remaining ``max_batched_tokens`` in
prefill-chunk tokens from admitting slots (FCFS), so a long prompt
prefills in budget-bounded chunks interleaved with decode instead of
stalling every slot for its whole forward.  Layer families that cannot
expose per-position paged history (ring/recurrent/MLA — the prefix
sharing opt-outs) fall back to bucketed whole-prompt admission.

This module is host-side bookkeeping only (allocator, slot states, trace
metrics); the device side lives in ``engine.serve_continuous`` (jitted
mixed step + fused multi-token decode scan) and
``kernels/decode_attention`` (paged single-query and mixed multi-query
kernels).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.scheduler import Request


class PageAllocator:
    """Refcounted free-list allocator over ``num_pages`` physical pages.

    Page ids are 0..num_pages-1; the engine reserves one extra pool page
    (id num_pages) as the dump page, which is never handed out.
    ``alloc`` hands out pages at refcount 1; ``incref`` adds a sharer
    (prefix cache or another request); ``decref`` releases one reference
    and returns the page to the free list at zero.  Refcounts can never
    go negative — a decref of an unallocated page raises.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def allocated_count(self) -> int:
        return len(self._ref)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages at refcount 1, or None (and no change) if the pool
        can't cover it."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def incref(self, page: int) -> None:
        if page not in self._ref:
            raise ValueError(f"incref of unallocated page {page}")
        self._ref[page] += 1

    def decref(self, page: int) -> None:
        if not (0 <= page < self.num_pages):
            raise ValueError(f"bad page id {page}")
        c = self._ref.get(page, 0)
        if c <= 0:
            raise ValueError(f"refcount of page {page} would go negative")
        if c == 1:
            del self._ref[page]
            self._free.append(page)
        else:
            self._ref[page] = c - 1

    def free(self, pages: List[int]) -> None:
        """Release one reference on each page.  Atomic: the whole batch
        is validated (ids in range, enough references to cover duplicate
        entries) before any page is released."""
        for p in pages:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"bad page id {p}")
            if pages.count(p) > self._ref.get(p, 0):
                raise ValueError(f"over-free of page {p}")
        for p in pages:
            self.decref(p)

    def check(self) -> None:
        """Pool accounting invariant: every page is either free or has a
        positive refcount, exactly once."""
        if len(set(self._free)) != len(self._free):
            raise AssertionError("duplicate pages in the free list")
        if set(self._free) & set(self._ref):
            raise AssertionError("page both free and allocated")
        if len(self._free) + len(self._ref) != self.num_pages:
            raise AssertionError(
                f"leak: {len(self._free)} free + {len(self._ref)} resident "
                f"!= {self.num_pages} pool pages")
        if any(c <= 0 for c in self._ref.values()):
            raise AssertionError("non-positive refcount")


@dataclass
class SlotState:
    request: Request
    pages: List[int]                   # block-table order (shared + fresh)
    fresh_pages: List[int] = field(default_factory=list)  # refcount-1, ours
    matched_len: int = 0               # tokens served from the prefix cache
    shared_count: int = 0              # leading fully-shared pages
    cow_src: int = -1                  # partial tail page to copy, or -1
    emitted: List[int] = field(default_factory=list)
    submitted_at: float = 0.0          # queued (arrival) time
    admitted_at: float = 0.0
    finished_at: Optional[float] = None
    # -- chunked prefill progress (unified token-budget scheduler) ----------
    prefill_pos: int = 0               # prompt tokens written so far (abs;
    #                                    starts at matched_len; == prompt_len
    #                                    once the slot is decoding)
    admit_seq: int = 0                 # FCFS tiebreak for prefill chunks
    needs_init: bool = True            # fresh pages not yet reset / COW'd
    last_token_at: Optional[float] = None   # wall time of last emit (ITL)

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= self.request.prompt_len


@dataclass
class ChunkPlan:
    """One prefill chunk scheduled into a mixed iteration: ``length``
    prompt tokens of ``slot``'s request starting at absolute prompt
    position ``start`` (chunk boundaries need not align to pages)."""
    slot: int
    start: int
    length: int


@dataclass
class MixedPlan:
    """One token-budget iteration: every decoding slot contributes
    ``decode_cost`` tokens, admitting slots share the remainder as
    prefill chunks (FCFS)."""
    decode_slots: List[int]
    chunks: List[ChunkPlan]
    decode_cost: int = 1

    @property
    def total_tokens(self) -> int:
        return (self.decode_cost * len(self.decode_slots)
                + sum(c.length for c in self.chunks))


@dataclass
class ServeMetrics:
    """Per-run counters for the continuous path (the bench compares these
    against the bucket batcher's padding behaviour)."""
    steps: int = 0                   # fused decode micro-steps executed
    slot_steps_active: int = 0       # slot-steps that carried a live request
    slot_steps_total: int = 0
    prefill_tokens: int = 0          # prompt tokens actually computed
    prefill_padded: int = 0          # bucket-padded prompt tokens
    generated_tokens: int = 0
    admitted: int = 0
    retired: int = 0
    rejected: int = 0                # could never fit the page pool
    latency_s: List[float] = field(default_factory=list)
    # -- prefix cache -------------------------------------------------------
    prefix_hits: int = 0             # admissions with a non-empty match
    prefix_matched_tokens: int = 0   # prefill tokens saved by sharing
    pages_shared: int = 0            # zero-copy page mappings
    cow_copies: int = 0              # partial tail pages copied on write
    prefix_evicted_pages: int = 0    # trie pages reclaimed under pressure
    # -- KV pool capacity (kv_dtype axis) -----------------------------------
    kv_dtype: str = "auto"           # pool storage mode this run served at
    kv_pool_bytes: int = 0           # total paged-pool bytes (incl. scales)
    kv_bytes_per_token: float = 0.0  # pool bytes / token of capacity
    peak_pages_in_use: int = 0       # high-water mark of allocated pages
    admission_stalls: int = 0        # syncs a free slot waited on the pool
    # -- speculative decoding -----------------------------------------------
    spec_mode: str = "off"           # drafter this run used (off|ngram|...)
    spec_k: int = 0                  # drafted tokens per slot per step
    drafted_tokens: int = 0          # drafts offered to the verifier
    accepted_tokens: int = 0         # drafts kept by the rejection sampler
    decode_tokens: int = 0           # tokens emitted by decode/verify steps
    #   (generated_tokens minus the one-per-request admission sample)
    # -- unified token-budget scheduler (chunked prefill) -------------------
    scheduler: str = "bucketed"      # "unified" (token budget) | "bucketed"
    max_batched_tokens: int = 0      # per-iteration token budget (0 = n/a)
    prefill_chunks: int = 0          # prefill chunk rows scheduled
    ttft_s: List[float] = field(default_factory=list)   # submit->first tok
    itl_s: List[float] = field(default_factory=list)    # inter-token gaps

    @property
    def decode_idle_frac(self) -> float:
        if not self.slot_steps_total:
            return 0.0
        return 1.0 - self.slot_steps_active / self.slot_steps_total

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verifier accepted (0 when the
        run drafted nothing — speculation off, or every slot rejected)."""
        if not self.drafted_tokens:
            return 0.0
        return self.accepted_tokens / self.drafted_tokens

    @property
    def tokens_per_forward(self) -> float:
        """Mean tokens emitted per live slot per decode/verify forward.
        Non-speculative serving is bounded by 1.0 (an EOS forward emits
        nothing); acceptance pushes speculative serving above it."""
        if not self.slot_steps_active:
            return 0.0
        return self.decode_tokens / self.slot_steps_active

    @property
    def prefill_pad_frac(self) -> float:
        # zero-token traces (no admissions / empty prompts) report 0 waste
        if not self.prefill_padded:
            return 0.0
        return 1.0 - self.prefill_tokens / self.prefill_padded

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens served from the prefix cache."""
        total = self.prefix_matched_tokens + self.prefill_tokens
        return self.prefix_matched_tokens / total if total else 0.0

    def percentile_latency(self, q: float) -> float:
        return float(np.percentile(self.latency_s, q)) if self.latency_s \
            else 0.0

    def percentile_ttft(self, q: float) -> float:
        """Time-to-first-token percentile (submission -> first emitted
        token); 0 for zero-token runs."""
        return float(np.percentile(self.ttft_s, q)) if self.ttft_s else 0.0

    def percentile_itl(self, q: float) -> float:
        """Inter-token-latency percentile over every emitted token after
        a slot's first (multi-token syncs spread their wall time evenly
        across the tokens they emitted); 0 for runs that never decoded
        past a first token."""
        return float(np.percentile(self.itl_s, q)) if self.itl_s else 0.0

    @property
    def ttft_p50(self) -> float:
        return self.percentile_ttft(50)

    @property
    def ttft_p99(self) -> float:
        return self.percentile_ttft(99)

    @property
    def itl_p50(self) -> float:
        return self.percentile_itl(50)

    @property
    def itl_p99(self) -> float:
        return self.percentile_itl(99)


class ContinuousScheduler:
    """FCFS admission control over decode slots + the refcounted page pool.

    The engine drives it:  ``waiting`` holds not-yet-admitted requests
    (arrival-gated when a trace supplies arrival offsets); ``admit``
    claims a slot + pages, ``retire`` releases them.  With a
    ``prefix_cache``, admission first matches the request's longest
    cached prefix: fully-covered pages are mapped shared (incref, zero
    prefill cost), a partially-covered tail page is flagged for
    copy-on-write, and only the fresh remainder is allocated — evicting
    LRU unreferenced trie leaves if the pool runs dry.
    """

    def __init__(self, max_slots: int, allocator: PageAllocator,
                 page_size: int, max_pages_per_slot: Optional[int] = None,
                 prefix_cache=None, match_prefix: bool = True):
        self.max_slots = max_slots
        self.allocator = allocator
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        self.prefix_cache = prefix_cache
        self.match_prefix = match_prefix and prefix_cache is not None
        self.waiting: List[Request] = []
        self.slots: Dict[int, SlotState] = {}      # slot idx -> state
        self._submit_t: Dict[int, float] = {}      # uid -> queued time
        self._admit_seq = 0                        # FCFS chunk ordering

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request, now: float = 0.0) -> None:
        self.waiting.append(req)
        self._submit_t[req.uid] = now

    def has_work(self) -> bool:
        return bool(self.waiting or self.slots)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.slots]

    def pages_needed(self, req: Request) -> int:
        total = req.prompt_len + req.max_new_tokens
        n = -(-total // self.page_size)
        if self.max_pages_per_slot is not None:
            # generation budget is clamped to the slot's max context at
            # admission, so never claim more than one slot can address
            n = min(n, self.max_pages_per_slot)
        return n

    def _alloc_with_eviction(self, n: int) -> Optional[List[int]]:
        pages = self.allocator.alloc(n)
        if pages is None and self.prefix_cache is not None:
            self.prefix_cache.evict(n - self.allocator.free_count)
            pages = self.allocator.alloc(n)
        return pages

    # -- admit / retire -----------------------------------------------------
    def try_admit(self, now: float = 0.0) -> Optional[tuple]:
        """Pop the head-of-line request into a free slot if the pool can
        hold it.  Returns (slot_idx, SlotState) or None.  FCFS: a stuck
        head (pool too full) blocks admission — freeing happens via
        retire and prefix-cache eviction, so this can't deadlock while
        any slot is live."""
        if not self.waiting:
            return None
        free = self.free_slots()
        if not free:
            return None
        req = self.waiting[0]
        total = self.pages_needed(req)
        matched, mpages = (0, [])
        if self.match_prefix and req.prompt_len > 1:
            # always leave >= 1 suffix token: its logits seed sampling
            matched, mpages = self.prefix_cache.match(
                req.tokens[:req.prompt_len - 1])
        shared = matched // self.page_size           # fully-covered pages
        cow_src = mpages[shared] if matched % self.page_size else -1
        # take references on every matched page BEFORE allocating: the
        # allocation may evict LRU trie leaves, and a bare trie reference
        # would make the matched pages themselves eviction candidates
        for p in mpages[:shared]:
            self.allocator.incref(p)                 # zero-copy mapping
        if cow_src >= 0:
            self.allocator.incref(cow_src)           # pin the COW source
        fresh = self._alloc_with_eviction(total - shared)
        if fresh is None:
            for p in mpages[:shared]:
                self.allocator.decref(p)
            if cow_src >= 0:
                self.allocator.decref(cow_src)
            return None
        self.waiting.pop(0)
        slot = free[0]
        st = SlotState(request=req, pages=mpages[:shared] + fresh,
                       fresh_pages=fresh, matched_len=matched,
                       shared_count=shared, cow_src=cow_src,
                       admitted_at=now,
                       submitted_at=self._submit_t.get(req.uid, 0.0),
                       prefill_pos=matched, admit_seq=self._admit_seq)
        self._admit_seq += 1
        req.prefix_tokens_matched = matched
        self.slots[slot] = st
        return slot, st

    # -- unified token-budget iteration planning ----------------------------
    def next_batch(self, budget: int, decode_cost: int = 1) -> MixedPlan:
        """Plan one mixed iteration under ``budget`` total tokens.

        Decode comes first: every decoding slot (prefill complete)
        contributes ``decode_cost`` tokens — inter-token latency is what
        the budget protects.  The remainder is dealt to admitting slots
        as prefill chunks in admission (FCFS) order, each chunk
        ``min(remaining prompt, remaining budget)`` tokens, so the
        oldest admitting slot always advances first and no slot starves:
        an admitting slot occupies a decode slot itself, so with
        ``budget >= max_slots * decode_cost`` at least one chunk token
        is always schedulable whenever any slot is admitting.
        """
        decode = [s for s in sorted(self.slots)
                  if self.slots[s].prefill_done]
        admitting = sorted((s for s in self.slots
                            if not self.slots[s].prefill_done),
                           key=lambda s: self.slots[s].admit_seq)
        rem = budget - decode_cost * len(decode)
        chunks: List[ChunkPlan] = []
        for s in admitting:
            if rem <= 0:
                break
            st = self.slots[s]
            c = min(st.request.prompt_len - st.prefill_pos, rem)
            chunks.append(ChunkPlan(slot=s, start=st.prefill_pos, length=c))
            rem -= c
        return MixedPlan(decode_slots=decode, chunks=chunks,
                         decode_cost=decode_cost)

    def release_cow_source(self, st: SlotState) -> None:
        """Drop the pin on the COW source page once the engine has copied
        it into the request's own tail page."""
        if st.cow_src >= 0:
            self.allocator.decref(st.cow_src)
            st.cow_src = -1

    def insert_prefix(self, st: SlotState, valid_len: int) -> int:
        """Index ``valid_len`` tokens of the slot's context (prompt, plus
        generated tokens at retire) into the prefix cache.  The engine
        calls this (a) right after the admission prefill with the
        page-aligned prompt span — pages that decode will still write
        into are excluded — and (b) at retire with the full finalized
        context."""
        if self.prefix_cache is None or not self.match_prefix \
                or valid_len <= 0:
            return 0
        toks = list(st.request.tokens) + st.emitted
        return self.prefix_cache.insert(toks[:valid_len], st.pages,
                                        valid_len)

    def retire(self, slot: int, now: float = 0.0) -> SlotState:
        st = self.slots.pop(slot)
        st.finished_at = now
        st.request.result = st.emitted[:st.request.max_new_tokens]
        self.release_cow_source(st)
        # finalized context -> cache it for future requests.  The last
        # emitted token's KV may never have been written (a budget-capped
        # request samples it without a further decode step), so it is
        # conservatively excluded.
        cached_gen = max(len(st.emitted) - 1, 0)
        self.insert_prefix(st, st.request.prompt_len + cached_gen)
        for p in st.pages:
            self.allocator.decref(p)
        return st
