"""Continuous (in-flight) batching — the serving-side successor of the
paper's §2.3 dynamic batching.

The bucket batcher (`DynamicBatcher`) drains whole batches: every request
decodes until the *longest* one finishes, and each batch allocates a fresh
dense cache.  Here, a fixed set of decode *slots* runs forever; requests
are admitted into free slots mid-flight and retired at EOS, so the decode
step is always as full as the traffic allows.  KV memory is a shared pool
of fixed-size pages (see ``kv_cache.PAGED_KEYS``): pages are refcounted —
allocated on admit, released on retire, and *shared* across requests with
a common prompt prefix through :class:`~repro.core.prefix_cache.
RadixPrefixCache` (a shared page is never written; copy-on-write hands
the writer a fresh copy of a partial tail page).

Scheduling is a **unified token-budget iteration** (chunked prefill):
each step, :meth:`ContinuousScheduler.next_batch` packs one decode token
per live slot plus up to the remaining ``max_batched_tokens`` in
prefill-chunk tokens from admitting slots (FCFS), so a long prompt
prefills in budget-bounded chunks interleaved with decode instead of
stalling every slot for its whole forward.  Layer families that cannot
expose per-position paged history (ring/recurrent/MLA — the prefix
sharing opt-outs) fall back to bucketed whole-prompt admission.

This module is host-side bookkeeping only (allocator, slot states, trace
metrics); the device side lives in ``engine.serve_continuous`` (jitted
mixed step + fused multi-token decode scan) and
``kernels/decode_attention`` (paged single-query and mixed multi-query
kernels).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import Request, RequestOutcome


class PageAllocator:
    """Refcounted free-list allocator over ``num_pages`` physical pages.

    Page ids are 0..num_pages-1; the engine reserves one extra pool page
    (id num_pages) as the dump page, which is never handed out.
    ``alloc`` hands out pages at refcount 1; ``incref`` adds a sharer
    (prefix cache or another request); ``decref`` releases one reference
    and returns the page to the free list at zero.  Refcounts can never
    go negative — a decref of an unallocated page raises.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))
        self._ref: Dict[int, int] = {}

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def allocated_count(self) -> int:
        return len(self._ref)

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages at refcount 1, or None (and no change) if the pool
        can't cover it."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def incref(self, page: int) -> None:
        if page not in self._ref:
            raise ValueError(f"incref of unallocated page {page}")
        self._ref[page] += 1

    def decref(self, page: int) -> None:
        if not (0 <= page < self.num_pages):
            raise ValueError(f"bad page id {page}")
        c = self._ref.get(page, 0)
        if c <= 0:
            raise ValueError(f"refcount of page {page} would go negative")
        if c == 1:
            del self._ref[page]
            self._free.append(page)
        else:
            self._ref[page] = c - 1

    def free(self, pages: List[int]) -> None:
        """Release one reference on each page.  Atomic: the whole batch
        is validated (ids in range, enough references to cover duplicate
        entries) before any page is released."""
        for p in pages:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"bad page id {p}")
            if pages.count(p) > self._ref.get(p, 0):
                raise ValueError(f"over-free of page {p}")
        for p in pages:
            self.decref(p)

    def check(self) -> None:
        """Pool accounting invariant: every page is either free or has a
        positive refcount, exactly once."""
        if len(set(self._free)) != len(self._free):
            raise AssertionError("duplicate pages in the free list")
        if set(self._free) & set(self._ref):
            raise AssertionError("page both free and allocated")
        if len(self._free) + len(self._ref) != self.num_pages:
            raise AssertionError(
                f"leak: {len(self._free)} free + {len(self._ref)} resident "
                f"!= {self.num_pages} pool pages")
        if any(c <= 0 for c in self._ref.values()):
            raise AssertionError("non-positive refcount")


class HostKVStore:
    """Byte-budgeted host-memory KV tier — the level below the device
    page pool in the degradation ladder.

    Entries are opaque blobs (:func:`~repro.core.kv_cache.offload_pages`
    snapshots) under caller-chosen keys.  Two citizen classes share the
    budget: *evictable* entries (prefix-cache spills — best-effort warm
    state) are dropped LRU to make room, *non-evictable* entries
    (preemption snapshots — correctness-critical until resumed) stay
    until popped.  A ``put`` that cannot fit even after evicting every
    evictable entry is refused, never raises: callers degrade (recompute
    the KV / drop the prefix) instead of failing the request.

    ``max_bytes=None`` is unbounded; ``0`` refuses everything (the
    host-tier-full fault mode).
    """

    def __init__(self, max_bytes: Optional[int] = None):
        self.max_bytes = max_bytes
        # key -> (blob, nbytes, evictable); OrderedDict order = LRU
        self._entries: "OrderedDict[object, tuple]" = OrderedDict()
        self.used_bytes = 0
        self.peak_bytes = 0
        self.spill_evictions = 0       # evictable entries dropped for room
        self.refused_puts = 0          # blobs that could not fit at all
        self.trace = None              # optional ServeTracer (set per serve)

    def __contains__(self, key) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def peek(self, key):
        """The blob under ``key`` (refreshing its LRU position), or None."""
        e = self._entries.get(key)
        if e is None:
            return None
        self._entries.move_to_end(key)
        return e[0]

    def pop(self, key):
        """Remove and return the blob under ``key`` (None if absent)."""
        e = self._entries.pop(key, None)
        if e is None:
            return None
        self.used_bytes -= e[1]
        return e[0]

    def put(self, key, blob, *, evictable: bool = True) -> bool:
        """Store ``blob`` under ``key`` (replacing any previous entry),
        evicting LRU evictable entries if the budget requires.  Returns
        False — and stores nothing — when it cannot fit."""
        from repro.core.kv_cache import blob_bytes
        nbytes = blob_bytes(blob)
        old = self._entries.pop(key, None)
        if old is not None:
            self.used_bytes -= old[1]
        if self.max_bytes is not None:
            if nbytes > self.max_bytes:
                if old is not None:        # replacement failed: entry gone
                    self.refused_puts += 1
                    if self.trace is not None:
                        self.trace.emit_now("host_refused", bytes=int(nbytes))
                    return False
                self.refused_puts += 1
                if self.trace is not None:
                    self.trace.emit_now("host_refused", bytes=int(nbytes))
                return False
            while self.used_bytes + nbytes > self.max_bytes:
                victim = next((k for k, e in self._entries.items()
                               if e[2]), None)
                if victim is None:
                    self.refused_puts += 1
                    if self.trace is not None:
                        self.trace.emit_now("host_refused", bytes=int(nbytes))
                    return False
                _, vb, _ = self._entries.pop(victim)
                self.used_bytes -= vb
                self.spill_evictions += 1
                if self.trace is not None:
                    self.trace.emit_now("host_evict", bytes=int(vb))
        self._entries[key] = (blob, nbytes, evictable)
        self.used_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)
        return True

    def check(self) -> None:
        """Accounting invariant: used_bytes matches the resident blobs
        and never exceeds the budget."""
        total = sum(e[1] for e in self._entries.values())
        if total != self.used_bytes:
            raise AssertionError(
                f"host tier leak: {self.used_bytes} booked != "
                f"{total} resident bytes")
        if self.max_bytes is not None and self.used_bytes > self.max_bytes:
            raise AssertionError("host tier over budget")


@dataclass
class FaultConfig:
    """Deterministic fault injection for ``serve_continuous`` — the
    overload test harness.  Every fault must degrade gracefully: each
    submitted request still ends with a terminal
    :class:`~repro.core.scheduler.RequestOutcome`, the allocator audit
    stays clean, and the serve loop terminates.

      hold_pages        steal this many free pool pages (pool-exhaustion
                        fault) once ``hold_after_admits`` admissions have
                        happened; released before the end-of-run audit
      hold_after_admits admissions to wait before stealing
      host_full         force the host tier to refuse every offload
                        (preemption degrades to recompute-resume, trie
                        spills degrade to plain eviction)
      oversize_uids     inflate these requests' prompts past the whole
                        pool before admission (truncate-or-reject path)
      collapse_arrivals ignore arrival offsets: every request lands at
                        t=0 (adversarial burst)
    """
    hold_pages: int = 0
    hold_after_admits: int = 0
    host_full: bool = False
    oversize_uids: Tuple[int, ...] = ()
    collapse_arrivals: bool = False


@dataclass
class PreemptedState:
    """Resume ticket for a preempted request (scheduler-internal,
    keyed by uid while the request waits in the queue again).

    ``blob`` is the host KV snapshot (None when the host tier was full —
    resume then re-prefills prompt + generated tokens, which is greedy
    bit-identical).  ``pending`` is the sampled-but-unwritten last token
    (= emitted[-1]); ``ctx_len`` the written context length; ``rem`` the
    remaining token budget at preemption.
    """
    blob: Optional[list]
    emitted: List[int]
    n_pages: int
    ctx_len: int
    pending: int
    rem: int


@dataclass
class SlotState:
    request: Request
    pages: List[int]                   # block-table order (shared + fresh)
    fresh_pages: List[int] = field(default_factory=list)  # refcount-1, ours
    matched_len: int = 0               # tokens served from the prefix cache
    shared_count: int = 0              # leading fully-shared pages
    cow_src: int = -1                  # partial tail page to copy, or -1
    emitted: List[int] = field(default_factory=list)
    submitted_at: float = 0.0          # queued (arrival) time
    admitted_at: float = 0.0
    finished_at: Optional[float] = None
    # -- chunked prefill progress (unified token-budget scheduler) ----------
    prefill_pos: int = 0               # prompt tokens written so far (abs;
    #                                    starts at matched_len; == prompt_len
    #                                    once the slot is decoding)
    admit_seq: int = 0                 # FCFS tiebreak for prefill chunks
    needs_init: bool = True            # fresh pages not yet reset / COW'd
    last_token_at: Optional[float] = None   # wall time of last emit (ITL)
    # -- preemption resume --------------------------------------------------
    restore_blob: Optional[list] = None  # host KV snapshot to scatter back
    resume_ctx: Optional[List[int]] = None  # recompute-resume: the context
    #                                    (prompt + pre-preemption output) to
    #                                    re-prefill in place of the prompt
    resume_pending: int = -1           # pre-preemption sampled token; decode
    #                                    continues from it (not a new sample)
    resume_rem: int = -1               # token budget left at preemption

    @property
    def is_resume(self) -> bool:
        return self.resume_pending >= 0

    @property
    def ctx(self) -> List[int]:
        """Tokens the slot must have written before it can decode: the
        prompt, or on a recompute-resume the prompt plus every token
        generated before the preemption (minus the pending one)."""
        return self.resume_ctx if self.resume_ctx is not None \
            else self.request.tokens

    @property
    def ctx_len(self) -> int:
        return len(self.ctx)

    @property
    def prefill_done(self) -> bool:
        return self.prefill_pos >= self.ctx_len


@dataclass
class ChunkPlan:
    """One prefill chunk scheduled into a mixed iteration: ``length``
    prompt tokens of ``slot``'s request starting at absolute prompt
    position ``start`` (chunk boundaries need not align to pages)."""
    slot: int
    start: int
    length: int


@dataclass
class MixedPlan:
    """One token-budget iteration: every decoding slot contributes
    ``decode_cost`` tokens, admitting slots share the remainder as
    prefill chunks (FCFS)."""
    decode_slots: List[int]
    chunks: List[ChunkPlan]
    decode_cost: int = 1

    @property
    def total_tokens(self) -> int:
        return (self.decode_cost * len(self.decode_slots)
                + sum(c.length for c in self.chunks))


@dataclass
class PackedBatch:
    """One mixed iteration flattened into a single token-packed ragged
    stream (decode tokens first, then prefill-chunk tokens, FCFS):
    the device sees ONE (1, T) dispatch instead of a fused decode step
    plus one padded forward per chunk.

    Stream arrays are ``width`` long (the iteration's global bucket);
    lanes past ``n_tokens`` are padding (slot_ids/positions -1, tokens
    0).  Segment arrays are ``max_slots`` long: entry i describes the
    i-th segment — its owning slot, stream offset, length, and the
    stream index of its LAST real token (where sampling reads logits);
    entries past ``n_segments`` carry seg_slots -1 / last_idx 0 and are
    discarded host-side.  The first ``n_decode`` segments are decode
    segments (1 token each), the rest are prefill chunks in plan order.
    """
    tokens: np.ndarray       # (T,) int32, 0-padded
    slot_ids: np.ndarray     # (T,) int32, -1-padded
    positions: np.ndarray    # (T,) int32 absolute, -1-padded
    seg_slots: np.ndarray    # (S,) int32 owning slot, -1-padded
    seg_start: np.ndarray    # (S,) int32 stream offset of the segment
    seg_len: np.ndarray      # (S,) int32 real tokens in the segment
    last_idx: np.ndarray     # (S,) int32 stream index of the last token
    n_decode: int            # leading decode segments
    n_segments: int          # live segments (decode + chunks)
    n_tokens: int            # real lanes (== plan.total_tokens)


@dataclass
class ServeMetrics:
    """Per-run counters for the continuous path (the bench compares these
    against the bucket batcher's padding behaviour)."""
    steps: int = 0                   # fused decode micro-steps executed
    slot_steps_active: int = 0       # slot-steps that carried a live request
    slot_steps_total: int = 0
    prefill_tokens: int = 0          # prompt tokens actually computed
    prefill_padded: int = 0          # bucket-padded prompt tokens
    generated_tokens: int = 0
    admitted: int = 0
    retired: int = 0
    rejected: int = 0                # could never fit the page pool
    latency_s: List[float] = field(default_factory=list)
    # -- prefix cache -------------------------------------------------------
    prefix_hits: int = 0             # admissions with a non-empty match
    prefix_matched_tokens: int = 0   # prefill tokens saved by sharing
    pages_shared: int = 0            # zero-copy page mappings
    cow_copies: int = 0              # partial tail pages copied on write
    prefix_evicted_pages: int = 0    # trie pages reclaimed under pressure
    # -- KV pool capacity (kv_dtype axis) -----------------------------------
    kv_dtype: str = "auto"           # pool storage mode this run served at
    kv_pool_bytes: int = 0           # total paged-pool bytes (incl. scales)
    kv_bytes_per_token: float = 0.0  # pool bytes / token of capacity
    # -- weight compression (weights_dtype axis) ----------------------------
    weight_dtype: str = "auto"       # serve-path weight storage this run
    weight_bytes: int = 0            # dense matmul weight bytes (post-quant,
    #   int8 codes + fp32 scales; the bytes every forward streams)
    weight_bytes_saved: int = 0      # dense-storage bytes removed by the axis
    peak_pages_in_use: int = 0       # high-water mark of allocated pages
    admission_stalls: int = 0        # syncs a free slot waited on the pool
    # -- speculative decoding -----------------------------------------------
    spec_mode: str = "off"           # drafter this run used (off|ngram|...)
    spec_k: int = 0                  # drafted tokens per slot per step
    drafted_tokens: int = 0          # drafts offered to the verifier
    accepted_tokens: int = 0         # drafts kept by the rejection sampler
    decode_tokens: int = 0           # tokens emitted by decode/verify steps
    #   (generated_tokens minus the one-per-request admission sample)
    # -- unified token-budget scheduler (chunked prefill) -------------------
    scheduler: str = "bucketed"      # "unified" (token budget) | "bucketed"
    max_batched_tokens: int = 0      # per-iteration token budget (0 = n/a)
    prefill_chunks: int = 0          # prefill chunk rows scheduled
    ttft_s: List[float] = field(default_factory=list)   # submit->first tok
    itl_s: List[float] = field(default_factory=list)    # inter-token gaps
    # -- packed execution (token-packed ragged iterations) ------------------
    host_s: float = 0.0              # serve-loop wall time minus device time
    device_s: float = 0.0            # time inside blocking device dispatches
    host_syncs: int = 0              # device->host result transfers (one per
    #   iteration on the coalesced mixed path, not one per dispatch)
    mixed_iters: int = 0             # iterations that carried prefill chunks
    mixed_dispatches: int = 0        # device dispatches those iterations made
    packed_tokens_real: int = 0      # real lanes across packed dispatches
    packed_tokens_padded: int = 0    # bucket lanes across packed dispatches
    # -- overload survivability (preemption + host KV tier) -----------------
    preemptions: int = 0             # slots evicted under pool pressure
    resumed: int = 0                 # preempted requests re-admitted
    offloaded_pages: int = 0         # pages snapshotted to the host tier
    restored_pages: int = 0          # pages brought back from the host tier
    host_bytes_used: int = 0         # host tier bytes at end of run
    host_bytes_peak: int = 0         # host tier high-water mark
    timed_out: int = 0               # queued requests cancelled at deadline
    deadline_misses: int = 0         # requests that died or finished late
    outcome_counts: Dict[str, int] = field(default_factory=dict)

    @property
    def decode_idle_frac(self) -> float:
        if not self.slot_steps_total:
            return 0.0
        return 1.0 - self.slot_steps_active / self.slot_steps_total

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verifier accepted (0 when the
        run drafted nothing — speculation off, or every slot rejected)."""
        if not self.drafted_tokens:
            return 0.0
        return self.accepted_tokens / self.drafted_tokens

    @property
    def tokens_per_forward(self) -> float:
        """Mean tokens emitted per live slot per decode/verify forward.
        Non-speculative serving is bounded by 1.0 (an EOS forward emits
        nothing); acceptance pushes speculative serving above it."""
        if not self.slot_steps_active:
            return 0.0
        return self.decode_tokens / self.slot_steps_active

    @property
    def prefill_pad_frac(self) -> float:
        # zero-token traces (no admissions / empty prompts) report 0 waste
        if not self.prefill_padded:
            return 0.0
        return 1.0 - self.prefill_tokens / self.prefill_padded

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prompt tokens served from the prefix cache."""
        total = self.prefix_matched_tokens + self.prefill_tokens
        return self.prefix_matched_tokens / total if total else 0.0

    @property
    def host_frac(self) -> float:
        """Fraction of serve wall time spent OFF-device (host scheduling,
        packing, bookkeeping) — the per-iteration overhead the packed
        path attacks; 0 for runs that never dispatched."""
        total = self.host_s + self.device_s
        return self.host_s / total if total else 0.0

    @property
    def dispatches_per_iter(self) -> float:
        """Mean device dispatches per MIXED iteration (iterations that
        carried prefill chunks): 1.0 on the packed path, ``1 + #chunks``
        (plus one for decode) on the bucketed mixed path; 0 when the run
        never mixed (pure-decode traces)."""
        if not self.mixed_iters:
            return 0.0
        return self.mixed_dispatches / self.mixed_iters

    @property
    def padded_token_frac(self) -> float:
        """Fraction of packed-stream lanes that were bucket padding
        (0 when the run never packed)."""
        if not self.packed_tokens_padded:
            return 0.0
        return 1.0 - self.packed_tokens_real / self.packed_tokens_padded

    @staticmethod
    def percentile(values, q: float) -> float:
        """Zero-length-guarded percentile: the shared helper behind every
        latency/TTFT/ITL quantile this struct reports.  Empty inputs give
        0.0 (zero-token runs) instead of numpy's empty-slice warning."""
        if len(values) == 0:
            return 0.0
        return float(np.percentile(np.asarray(values, dtype=np.float64), q))

    def percentile_latency(self, q: float) -> float:
        return self.percentile(self.latency_s, q)

    def percentile_ttft(self, q: float) -> float:
        """Time-to-first-token percentile (submission -> first emitted
        token); 0 for zero-token runs."""
        return self.percentile(self.ttft_s, q)

    def percentile_itl(self, q: float) -> float:
        """Inter-token-latency percentile over every emitted token after
        a slot's first (multi-token syncs spread their wall time evenly
        across the tokens they emitted); 0 for runs that never decoded
        past a first token."""
        return self.percentile(self.itl_s, q)

    @property
    def ttft_p50(self) -> float:
        return self.percentile_ttft(50)

    @property
    def ttft_p99(self) -> float:
        return self.percentile_ttft(99)

    @property
    def itl_p50(self) -> float:
        return self.percentile_itl(50)

    @property
    def itl_p99(self) -> float:
        return self.percentile_itl(99)

    def to_dict(self, include_raw: bool = False) -> Dict[str, object]:
        """Complete metrics dump: every counter field plus every derived
        property (the quantities dashboards actually want), so consumers
        of ``--metrics-json`` never re-derive rates by hand.  Raw
        per-request sample lists are summarized as percentiles unless
        ``include_raw`` is set."""
        raw_lists = ("latency_s", "ttft_s", "itl_s")
        d: Dict[str, object] = {}
        for name in self.__dataclass_fields__:
            if name in raw_lists and not include_raw:
                continue
            v = getattr(self, name)
            d[name] = dict(v) if isinstance(v, dict) else v
        d.update(
            latency_p50=self.percentile_latency(50),
            latency_p99=self.percentile_latency(99),
            ttft_p50=self.ttft_p50,
            ttft_p99=self.ttft_p99,
            itl_p50=self.itl_p50,
            itl_p99=self.itl_p99,
            decode_idle_frac=self.decode_idle_frac,
            acceptance_rate=self.acceptance_rate,
            tokens_per_forward=self.tokens_per_forward,
            prefill_pad_frac=self.prefill_pad_frac,
            prefix_hit_rate=self.prefix_hit_rate,
            host_frac=self.host_frac,
            dispatches_per_iter=self.dispatches_per_iter,
            padded_token_frac=self.padded_token_frac,
        )
        return d


class ContinuousScheduler:
    """FCFS admission control over decode slots + the refcounted page pool.

    The engine drives it:  ``waiting`` holds not-yet-admitted requests
    (arrival-gated when a trace supplies arrival offsets); ``admit``
    claims a slot + pages, ``retire`` releases them.  With a
    ``prefix_cache``, admission first matches the request's longest
    cached prefix: fully-covered pages are mapped shared (incref, zero
    prefill cost), a partially-covered tail page is flagged for
    copy-on-write, and only the fresh remainder is allocated — evicting
    LRU unreferenced trie leaves if the pool runs dry.
    """

    def __init__(self, max_slots: int, allocator: PageAllocator,
                 page_size: int, max_pages_per_slot: Optional[int] = None,
                 prefix_cache=None, match_prefix: bool = True,
                 preemption: str = "off", max_preemptions: int = 2,
                 trace=None):
        self.max_slots = max_slots
        self.trace = trace             # optional ServeTracer (decision events)
        self.allocator = allocator
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        self.prefix_cache = prefix_cache
        self.match_prefix = match_prefix and prefix_cache is not None
        self.waiting: List[Request] = []
        self.slots: Dict[int, SlotState] = {}      # slot idx -> state
        self._submit_t: Dict[int, float] = {}      # uid -> queued time
        self._admit_seq = 0                        # FCFS chunk ordering
        # -- overload survivability ----------------------------------------
        if preemption not in ("off", "lru", "priority"):
            raise ValueError(f"unknown preemption policy {preemption!r}")
        self.preemption = preemption
        # a request preempted this many times becomes victim-ineligible
        # (with back-of-queue re-entry this bounds preempt/resume churn)
        self.max_preemptions = max_preemptions
        self.host_store: Optional[HostKVStore] = None
        # engine-injected device closures (host-side scheduler stays
        # device-free): offload_fn(pages) -> blob, restore_fn(blob, pages)
        self.offload_fn: Optional[Callable] = None
        self.restore_fn: Optional[Callable] = None
        self._resume: Dict[int, PreemptedState] = {}   # uid -> ticket
        self.promoted_pages = 0        # host->device trie re-promotions

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request, now: float = 0.0) -> None:
        self.waiting.append(req)
        self._submit_t[req.uid] = now
        if self.trace is not None:
            self.trace.emit("enqueue", t=now, uid=req.uid,
                            prompt_len=req.prompt_len,
                            max_new=req.max_new_tokens,
                            deadline=req.deadline)

    def has_work(self) -> bool:
        return bool(self.waiting or self.slots)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.slots]

    def pages_needed(self, req: Request) -> int:
        total = req.prompt_len + req.max_new_tokens
        n = -(-total // self.page_size)
        if self.max_pages_per_slot is not None:
            # generation budget is clamped to the slot's max context at
            # admission, so never claim more than one slot can address
            n = min(n, self.max_pages_per_slot)
        return n

    def _alloc_with_eviction(self, n: int) -> Optional[List[int]]:
        pages = self.allocator.alloc(n)
        if pages is None and self.prefix_cache is not None:
            self.prefix_cache.evict(n - self.allocator.free_count)
            pages = self.allocator.alloc(n)
        return pages

    # -- deadlines / backpressure -------------------------------------------
    def queued_pages_needed(self, req: Request) -> int:
        """Worst-case pages the queued head will claim — a preempted
        request resumes into exactly the page count it held."""
        pr = self._resume.get(req.uid)
        return pr.n_pages if pr is not None else self.pages_needed(req)

    def _finalize(self, req: Request, status: str, detail: str = "",
                  deadline_missed: bool = False) -> None:
        """Terminal bookkeeping for a request that will never (re)run:
        drop any resume ticket (preserving pre-preemption output as the
        partial result) and attach the structured outcome."""
        pr = self._resume.pop(req.uid, None)
        if pr is not None and pr.blob is not None \
                and self.host_store is not None:
            self.host_store.pop(("preempt", req.uid))
        req.result = pr.emitted[:req.max_new_tokens] if pr is not None \
            else []
        req.outcome = RequestOutcome(status=status,
                                     preemptions=req.preemptions,
                                     deadline_missed=deadline_missed,
                                     detail=detail)

    def cancel_expired(self, now: float = 0.0) -> List[Request]:
        """Backpressure sweep over the queue: cancel requests whose
        deadline or ``max_queue_wait`` has passed (``timed_out``) and
        requests that can never fit the pool (``rejected``) — serving
        stale work would only steal capacity from requests that can
        still meet theirs.  Running slots are never cancelled.  Returns
        the cancelled requests with terminal outcomes attached."""
        kept, cancelled = [], []
        for req in self.waiting:
            status = detail = None
            missed = False
            waited = now - self._submit_t.get(req.uid, 0.0)
            if req.deadline is not None and now > req.deadline:
                status, missed = "timed_out", True
                detail = f"deadline {req.deadline:.3f}s passed in queue"
            elif req.max_queue_wait is not None \
                    and waited > req.max_queue_wait:
                status, missed = "timed_out", True
                detail = (f"queued {waited:.3f}s > max_queue_wait "
                          f"{req.max_queue_wait:.3f}s")
            elif self.queued_pages_needed(req) > self.allocator.num_pages:
                status = "rejected"
                detail = (f"needs {self.queued_pages_needed(req)} pages, "
                          f"pool holds {self.allocator.num_pages}")
            if status is None:
                kept.append(req)
                continue
            self._finalize(req, status, detail, deadline_missed=missed)
            cancelled.append(req)
            if self.trace is not None:
                self.trace.emit("cancel", t=now, uid=req.uid,
                                status=status, detail=detail)
        self.waiting = kept
        return cancelled

    def fail_head(self, detail: str = "") -> Optional[Request]:
        """Reject the head-of-line request (the engine's no-slots escape
        hatch: nothing is running, eviction already ran, and the head
        still cannot fit — spinning would deadlock the loop)."""
        if not self.waiting:
            return None
        req = self.waiting.pop(0)
        self._finalize(req, "rejected", detail)
        if self.trace is not None:
            self.trace.emit_now("cancel", uid=req.uid, status="rejected",
                                detail=detail)
        return req

    # -- preemption ---------------------------------------------------------
    def preempt_candidates(self, beneficiary: Request) -> List[int]:
        """Slots eligible to be preempted for ``beneficiary`` under the
        configured policy.  Only *decoding* slots qualify: preempting a
        mid-prefill slot would throw away its prefill for no freed-up
        decode capacity (it becomes preemptible the moment its prefill
        completes).  A request that already burned ``max_preemptions``
        is protected from further eviction."""
        if self.preemption == "off":
            return []
        out = []
        for s, st in self.slots.items():
            r = st.request
            if not st.prefill_done or not st.emitted:
                continue
            if r.preemptions >= self.max_preemptions:
                continue
            if self.preemption == "priority" \
                    and r.priority >= beneficiary.priority:
                continue
            out.append(s)
        return out

    def pick_victim(self, beneficiary: Request) -> Optional[int]:
        """The slot to evict for ``beneficiary``: lowest priority first,
        most recently admitted as the tiebreak (the LRU policy reduces
        to pure most-recently-admitted) — the oldest work in flight is
        closest to completion and keeps its slot."""
        cands = self.preempt_candidates(beneficiary)
        if not cands:
            return None
        return max(cands, key=lambda s: (-self.slots[s].request.priority,
                                         self.slots[s].admit_seq))

    def preemptible_headroom(self, beneficiary: Request) -> int:
        """Upper bound on pages an admission could obtain via the free
        list + trie eviction + preempting every eligible victim.  A head
        needing more than this can never be helped by preemption, so the
        engine must not start evicting victims for it."""
        evictable = self.prefix_cache.evictable_count() \
            if self.prefix_cache is not None else 0
        return (self.allocator.free_count + evictable
                + sum(len(self.slots[s].pages)
                      for s in self.preempt_candidates(beneficiary)))

    def preempt(self, slot: int, *, pending: int, ctx_len: int,
                rem_tokens: int) -> Tuple[SlotState, bool]:
        """Evict a decoding slot under pool pressure: snapshot its paged
        KV into the host tier (when one is attached and has room), free
        its device pages, and re-queue the request at the BACK of the
        queue with its generated tokens preserved.  Back-of-queue
        re-entry is what breaks the preempt/resume livelock: the
        beneficiary admits into the freed pages before the victim can
        reclaim them.  Returns (victim state, offloaded?).

        ``pending``/``ctx_len``/``rem_tokens`` come from the engine's
        slot arrays: the sampled-but-unwritten token, written context
        length, and remaining budget at the preemption point.
        """
        st = self.slots.pop(slot)
        req = st.request
        assert st.prefill_done and st.emitted, \
            "only decoding slots are preemptible"
        req.preemptions += 1
        blob = None
        if self.host_store is not None and self.offload_fn is not None:
            blob = self.offload_fn(st.pages)
            if not self.host_store.put(("preempt", req.uid), blob,
                                       evictable=False):
                blob = None            # host tier full: recompute-resume
        self.release_cow_source(st)
        for p in st.pages:
            self.allocator.decref(p)
        self._resume[req.uid] = PreemptedState(
            blob=blob, emitted=list(st.emitted), n_pages=len(st.pages),
            ctx_len=ctx_len, pending=pending, rem=rem_tokens)
        self.waiting.append(req)
        return st, blob is not None

    def _try_resume(self, req: Request, pr: PreemptedState, slot: int,
                    now: float) -> Optional[tuple]:
        """Re-admit a preempted request: allocate the page count it held,
        then either mark the slot for a host-tier restore (the engine
        scatters the blob back; decode resumes bit-identically) or set
        up a recompute-resume (re-prefill prompt + generated tokens as
        ordinary chunks, then continue from the preserved pending
        token — greedy bit-identical, just not free)."""
        pages = self._alloc_with_eviction(pr.n_pages)
        if pages is None:
            return None
        self.waiting.pop(0)
        self._resume.pop(req.uid)
        st = SlotState(request=req, pages=pages, fresh_pages=pages,
                       admitted_at=now,
                       submitted_at=self._submit_t.get(req.uid, 0.0),
                       admit_seq=self._admit_seq)
        self._admit_seq += 1
        st.emitted = list(pr.emitted)
        st.resume_ctx = list(req.tokens) + pr.emitted[:-1]
        assert len(st.resume_ctx) == pr.ctx_len, \
            "resume context desynchronized from written KV length"
        st.resume_pending = pr.pending
        st.resume_rem = pr.rem
        if pr.blob is not None:
            if self.host_store is not None:
                self.host_store.pop(("preempt", req.uid))
            st.restore_blob = pr.blob
            st.prefill_pos = pr.ctx_len    # KV comes back verbatim
            st.needs_init = False
        self.slots[slot] = st
        if self.trace is not None:
            self.trace.emit(
                "admit", t=now, uid=req.uid, slot=slot, matched_tokens=0,
                pages=len(pages),
                resume="hostkv" if pr.blob is not None else "recompute")
        return slot, st

    def _promote(self, tokens: List[int], matched: int,
                 mpages: List[int]) -> Tuple[int, List[int]]:
        """Extend a trie match from the host spill tier: while the next
        full page span of ``tokens`` is spilled, allocate a device page,
        restore the span's KV into it, and re-insert it into the trie.
        Each promoted page enters holding both the trie's reference and
        the caller's mapping reference (so a later eviction inside this
        same admission cannot free it).  Returns the extended
        (matched, pages)."""
        ps = self.page_size
        while matched % ps == 0 and matched + ps <= len(tokens):
            key = ("trie", tuple(tokens[:matched + ps]))
            blob = self.host_store.peek(key)
            if blob is None:
                break
            pg = self._alloc_with_eviction(1)
            if pg is None:
                break
            self.restore_fn(blob, pg)
            self.host_store.pop(key)
            # alloc's reference becomes the request mapping; the trie
            # takes its own via insert's incref
            self.prefix_cache.insert(tokens[:matched + ps],
                                     mpages + pg, matched + ps)
            mpages = mpages + pg
            matched += ps
            self.promoted_pages += 1
        return matched, mpages

    # -- admit / retire -----------------------------------------------------
    def try_admit(self, now: float = 0.0) -> Optional[tuple]:
        """Pop the head-of-line request into a free slot if the pool can
        hold it.  Returns (slot_idx, SlotState) or None.  FCFS: a stuck
        head (pool too full) blocks admission — freeing happens via
        retire, prefix-cache eviction and (when enabled) preemption, so
        this can't deadlock while any slot is live."""
        if not self.waiting:
            return None
        free = self.free_slots()
        if not free:
            if self.trace is not None:
                self.trace.emit("admission_denied", t=now,
                                uid=self.waiting[0].uid,
                                reason="no_free_slot")
            return None
        req = self.waiting[0]
        pr = self._resume.get(req.uid)
        if pr is not None:
            res = self._try_resume(req, pr, free[0], now)
            if res is None and self.trace is not None:
                self.trace.emit("admission_denied", t=now, uid=req.uid,
                                reason="pool_exhausted_resume",
                                pages_needed=pr.n_pages)
            return res
        total = self.pages_needed(req)
        matched, mpages = (0, [])
        if self.match_prefix and req.prompt_len > 1:
            # always leave >= 1 suffix token: its logits seed sampling
            matched, mpages = self.prefix_cache.match(
                req.tokens[:req.prompt_len - 1])
        shared = matched // self.page_size           # fully-covered pages
        cow_src = mpages[shared] if matched % self.page_size else -1
        # take references on every matched page BEFORE allocating: the
        # allocation may evict LRU trie leaves, and a bare trie reference
        # would make the matched pages themselves eviction candidates
        for p in mpages[:shared]:
            self.allocator.incref(p)                 # zero-copy mapping
        if cow_src >= 0:
            self.allocator.incref(cow_src)           # pin the COW source
        if cow_src < 0 and self.match_prefix and self.host_store is not None \
                and self.restore_fn is not None and req.prompt_len > 1:
            # page-aligned match end: the continuation may be spilled
            matched, mpages = self._promote(
                list(req.tokens[:req.prompt_len - 1]), matched, mpages)
            shared = matched // self.page_size
        fresh = self._alloc_with_eviction(total - shared)
        if fresh is None:
            for p in mpages[:shared]:
                self.allocator.decref(p)
            if cow_src >= 0:
                self.allocator.decref(cow_src)
            if self.trace is not None:
                self.trace.emit("admission_denied", t=now, uid=req.uid,
                                reason="pool_exhausted",
                                pages_needed=total - shared)
            return None
        self.waiting.pop(0)
        slot = free[0]
        st = SlotState(request=req, pages=mpages[:shared] + fresh,
                       fresh_pages=fresh, matched_len=matched,
                       shared_count=shared, cow_src=cow_src,
                       admitted_at=now,
                       submitted_at=self._submit_t.get(req.uid, 0.0),
                       prefill_pos=matched, admit_seq=self._admit_seq)
        self._admit_seq += 1
        req.prefix_tokens_matched = matched
        self.slots[slot] = st
        if self.trace is not None:
            self.trace.emit("admit", t=now, uid=req.uid, slot=slot,
                            matched_tokens=matched, pages=len(st.pages),
                            resume="no")
        return slot, st

    # -- unified token-budget iteration planning ----------------------------
    def next_batch(self, budget: int, decode_cost: int = 1) -> MixedPlan:
        """Plan one mixed iteration under ``budget`` total tokens.

        Decode comes first: every decoding slot (prefill complete)
        contributes ``decode_cost`` tokens — inter-token latency is what
        the budget protects.  The remainder is dealt to admitting slots
        as prefill chunks in admission (FCFS) order, each chunk
        ``min(remaining prompt, remaining budget)`` tokens, so the
        oldest admitting slot always advances first and no slot starves:
        an admitting slot occupies a decode slot itself, so with
        ``budget >= max_slots * decode_cost`` at least one chunk token
        is always schedulable whenever any slot is admitting.
        """
        decode = [s for s in sorted(self.slots)
                  if self.slots[s].prefill_done]
        admitting = sorted((s for s in self.slots
                            if not self.slots[s].prefill_done),
                           key=lambda s: self.slots[s].admit_seq)
        rem = budget - decode_cost * len(decode)
        chunks: List[ChunkPlan] = []
        for s in admitting:
            if rem <= 0:
                break
            st = self.slots[s]
            c = min(st.ctx_len - st.prefill_pos, rem)
            chunks.append(ChunkPlan(slot=s, start=st.prefill_pos, length=c))
            rem -= c
        return MixedPlan(decode_slots=decode, chunks=chunks,
                         decode_cost=decode_cost)

    def pack_batch(self, plan: MixedPlan, pending_tok, lengths,
                   width: int) -> PackedBatch:
        """Flatten a :meth:`next_batch` plan into one token-packed ragged
        stream (:class:`PackedBatch`): decode segments first — slot s
        contributes its pending token ``pending_tok[s]`` at position
        ``lengths[s]`` — then each prefill chunk's prompt tokens, in plan
        (FCFS) order.  ``width`` is the iteration's global stream-width
        bucket; the caller picks it so ``plan.total_tokens <= width``.
        Packing preserves the plan verbatim (budget, decode-first, FCFS
        chunk order — property-tested), it only changes the layout the
        device sees."""
        assert plan.decode_cost == 1, \
            "packed execution streams exactly one decode token per slot"
        assert plan.total_tokens <= width, \
            f"plan of {plan.total_tokens} tokens exceeds bucket {width}"
        S = self.max_slots
        tokens = np.zeros(width, np.int32)
        slot_ids = np.full(width, -1, np.int32)
        positions = np.full(width, -1, np.int32)
        seg_slots = np.full(S, -1, np.int32)
        seg_start = np.zeros(S, np.int32)
        seg_len = np.zeros(S, np.int32)
        last_idx = np.zeros(S, np.int32)
        t = i = 0
        for s in plan.decode_slots:
            tokens[t] = pending_tok[s]
            slot_ids[t] = s
            positions[t] = lengths[s]
            seg_slots[i], seg_start[i], seg_len[i], last_idx[i] = s, t, 1, t
            t += 1
            i += 1
        for c in plan.chunks:
            ctx = self.slots[c.slot].ctx
            tokens[t:t + c.length] = ctx[c.start:c.start + c.length]
            slot_ids[t:t + c.length] = c.slot
            positions[t:t + c.length] = np.arange(c.start,
                                                  c.start + c.length)
            seg_slots[i], seg_start[i] = c.slot, t
            seg_len[i], last_idx[i] = c.length, t + c.length - 1
            t += c.length
            i += 1
        return PackedBatch(tokens=tokens, slot_ids=slot_ids,
                           positions=positions, seg_slots=seg_slots,
                           seg_start=seg_start, seg_len=seg_len,
                           last_idx=last_idx,
                           n_decode=len(plan.decode_slots),
                           n_segments=i, n_tokens=t)

    def release_cow_source(self, st: SlotState) -> None:
        """Drop the pin on the COW source page once the engine has copied
        it into the request's own tail page."""
        if st.cow_src >= 0:
            self.allocator.decref(st.cow_src)
            st.cow_src = -1

    def insert_prefix(self, st: SlotState, valid_len: int) -> int:
        """Index ``valid_len`` tokens of the slot's context (prompt, plus
        generated tokens at retire) into the prefix cache.  The engine
        calls this (a) right after the admission prefill with the
        page-aligned prompt span — pages that decode will still write
        into are excluded — and (b) at retire with the full finalized
        context."""
        if self.prefix_cache is None or not self.match_prefix \
                or valid_len <= 0:
            return 0
        toks = list(st.request.tokens) + st.emitted
        return self.prefix_cache.insert(toks[:valid_len], st.pages,
                                        valid_len)

    def retire(self, slot: int, now: float = 0.0) -> SlotState:
        st = self.slots.pop(slot)
        st.finished_at = now
        req = st.request
        st.request.result = st.emitted[:st.request.max_new_tokens]
        req.outcome = RequestOutcome(
            status="truncated" if req.truncated else "completed",
            preemptions=req.preemptions,
            deadline_missed=(req.deadline is not None
                             and st.finished_at > req.deadline))
        self.release_cow_source(st)
        # finalized context -> cache it for future requests.  The last
        # emitted token's KV may never have been written (a budget-capped
        # request samples it without a further decode step), so it is
        # conservatively excluded.
        cached_gen = max(len(st.emitted) - 1, 0)
        self.insert_prefix(st, st.request.prompt_len + cached_gen)
        for p in st.pages:
            self.allocator.decref(p)
        return st
