"""Continuous (in-flight) batching — the serving-side successor of the
paper's §2.3 dynamic batching.

The bucket batcher (`DynamicBatcher`) drains whole batches: every request
decodes until the *longest* one finishes, and each batch allocates a fresh
dense cache.  Here, a fixed set of decode *slots* runs forever; requests
are admitted into free slots mid-flight and retired at EOS, so the decode
step is always as full as the traffic allows.  KV memory is a shared pool
of fixed-size pages (see ``kv_cache.PAGED_KEYS``): pages are allocated on
admit and freed on retire, so memory tracks the *actual* context lengths
instead of slots * max_len.

This module is host-side bookkeeping only (allocator, slot states, trace
metrics); the device side lives in ``engine.serve_continuous`` (jitted
admit + fused multi-token decode step) and ``kernels/decode_attention``
(paged kernel).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.scheduler import Request


class PageAllocator:
    """Free-list allocator over ``num_pages`` physical pages.

    Page ids are 0..num_pages-1; the engine reserves one extra pool page
    (id num_pages) as the dump page, which is never handed out.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free: List[int] = list(range(num_pages - 1, -1, -1))

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n pages, or None (and no change) if the pool can't cover it."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if not (0 <= p < self.num_pages):
                raise ValueError(f"bad page id {p}")
        if len(set(pages)) != len(pages) or set(pages) & set(self._free):
            raise ValueError("double free")
        self._free.extend(pages)


@dataclass
class SlotState:
    request: Request
    pages: List[int]
    emitted: List[int] = field(default_factory=list)
    submitted_at: float = 0.0          # queued (arrival) time
    admitted_at: float = 0.0
    finished_at: Optional[float] = None


@dataclass
class ServeMetrics:
    """Per-run counters for the continuous path (the bench compares these
    against the bucket batcher's padding behaviour)."""
    steps: int = 0                   # fused decode micro-steps executed
    slot_steps_active: int = 0       # slot-steps that carried a live request
    slot_steps_total: int = 0
    prefill_tokens: int = 0          # real prompt tokens prefetched
    prefill_padded: int = 0          # bucket-padded prompt tokens
    generated_tokens: int = 0
    admitted: int = 0
    retired: int = 0
    rejected: int = 0                # could never fit the page pool
    latency_s: List[float] = field(default_factory=list)

    @property
    def decode_idle_frac(self) -> float:
        if not self.slot_steps_total:
            return 0.0
        return 1.0 - self.slot_steps_active / self.slot_steps_total

    @property
    def prefill_pad_frac(self) -> float:
        if not self.prefill_padded:
            return 0.0
        return 1.0 - self.prefill_tokens / self.prefill_padded

    def percentile_latency(self, q: float) -> float:
        return float(np.percentile(self.latency_s, q)) if self.latency_s \
            else 0.0


class ContinuousScheduler:
    """FCFS admission control over decode slots + the page pool.

    The engine drives it:  ``waiting`` holds not-yet-admitted requests
    (arrival-gated when a trace supplies arrival offsets); ``admit``
    claims a slot + pages, ``retire`` releases them.
    """

    def __init__(self, max_slots: int, allocator: PageAllocator,
                 page_size: int, max_pages_per_slot: Optional[int] = None):
        self.max_slots = max_slots
        self.allocator = allocator
        self.page_size = page_size
        self.max_pages_per_slot = max_pages_per_slot
        self.waiting: List[Request] = []
        self.slots: Dict[int, SlotState] = {}      # slot idx -> state
        self._submit_t: Dict[int, float] = {}      # uid -> queued time

    # -- queue --------------------------------------------------------------
    def submit(self, req: Request, now: float = 0.0) -> None:
        self.waiting.append(req)
        self._submit_t[req.uid] = now

    def has_work(self) -> bool:
        return bool(self.waiting or self.slots)

    def free_slots(self) -> List[int]:
        return [s for s in range(self.max_slots) if s not in self.slots]

    def pages_needed(self, req: Request) -> int:
        total = req.prompt_len + req.max_new_tokens
        n = -(-total // self.page_size)
        if self.max_pages_per_slot is not None:
            # generation budget is clamped to the slot's max context at
            # admission, so never claim more than one slot can address
            n = min(n, self.max_pages_per_slot)
        return n

    # -- admit / retire -----------------------------------------------------
    def try_admit(self, now: float = 0.0) -> Optional[tuple]:
        """Pop the head-of-line request into a free slot if the pool can
        hold it.  Returns (slot_idx, SlotState) or None.  FCFS: a stuck
        head (pool too full) blocks admission — freeing happens via
        retire, so this can't deadlock while any slot is live."""
        if not self.waiting:
            return None
        free = self.free_slots()
        if not free:
            return None
        req = self.waiting[0]
        pages = self.allocator.alloc(self.pages_needed(req))
        if pages is None:
            return None
        self.waiting.pop(0)
        slot = free[0]
        st = SlotState(request=req, pages=pages, admitted_at=now,
                       submitted_at=self._submit_t.get(req.uid, 0.0))
        self.slots[slot] = st
        return slot, st

    def retire(self, slot: int, now: float = 0.0) -> SlotState:
        st = self.slots.pop(slot)
        st.finished_at = now
        st.request.result = st.emitted[:st.request.max_new_tokens]
        self.allocator.free(st.pages)
        return st
