"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

MUST be the very first two lines (before any jax-touching import): force
512 placeholder host devices so the production meshes can be built.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402

from repro.configs.registry import ASSIGNED, get_config   # noqa: E402
from repro.launch import hlo_analysis as HA               # noqa: E402
from repro.launch import mesh as M                        # noqa: E402
from repro.launch.specs import INPUT_SHAPES, make_target  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "../../../experiments/dryrun")

# ---------------------------------------------------------------------------
# One combo
# ---------------------------------------------------------------------------


def run_one(arch: str, shape: str, multi_pod: bool) -> dict:
    from repro.sharding import partition as SH
    cfg = get_config(arch)
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    SH.set_current_mesh(mesh)          # enables in-model constraints
    chips = mesh.size
    target = make_target(cfg, shape, mesh)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
           **target.static_meta}

    t0 = time.perf_counter()
    with mesh:
        jitted = jax.jit(target.fn, donate_argnums=target.donate_argnums)
        lowered = jitted.lower(*target.args)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()

    rec["lower_s"] = round(t1 - t0, 2)
    rec["compile_s"] = round(t2 - t1, 2)

    # -- memory ------------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
        if hasattr(ma, "peak_memory_in_bytes"):
            rec["memory"]["peak_memory_in_bytes"] = int(ma.peak_memory_in_bytes)
    except Exception as e:  # CPU backend may not support it
        rec["memory"] = {"error": str(e)}

    # -- cost ----------------------------------------------------------------
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       (k in ("flops", "bytes accessed", "transcendentals")
                        or k.startswith("bytes accessed"))}
    except Exception as e:
        rec["cost"] = {"error": str(e)}

    # -- trip-count-aware HLO analysis (flops/bytes/collectives) ----------
    try:
        hlo = compiled.as_text()
        ha = HA.analyze(hlo)
        rec["hlo"] = {"flops": ha["flops"], "bytes": ha["bytes"],
                      "n_dots": ha["n_dots"],
                      "bytes_by_op": ha["bytes_by_op"]}
        rec["collectives"] = ha["collectives"]
        rec["hlo_lines"] = hlo.count("\n")
    except Exception as e:
        rec["collectives"] = {"error": str(e)}
        rec["hlo"] = {"error": str(e)}

    # -- model flops (roofline 'useful compute') ----------------------------
    pc = cfg.param_counts()
    info = INPUT_SHAPES[shape]
    tokens = info["batch"] * (info["seq"] if shape != "decode_32k" else 1)
    if info["kind"] == "decode":
        tokens = info["batch"]  # one token per slot
    nonembed_total = pc["total"] - pc["embed"]
    nonembed_active = pc["active"] - pc["embed"]
    mult = 6 if info["kind"] == "train" else 2
    rec["model_flops"] = {
        "params_total": pc["total"], "params_active": pc["active"],
        "tokens": tokens,
        "flops": mult * nonembed_active * tokens,
    }
    return rec


def applicable(arch: str, shape: str) -> bool:
    return True  # every combo lowers (long-context override covers 500k)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every combo in subprocesses")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--suffix", default=None,
                    help="artifact tag suffix for §Perf variants")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        failures = []
        combos = [(a, s, mp)
                  for a in ASSIGNED
                  for s in INPUT_SHAPES
                  for mp in ((False, True) if args.both_meshes else (False,))]
        for i, (a, s, mp) in enumerate(combos):
            tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[{i+1}/{len(combos)}] {tag}: cached", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True)
            ok = r.returncode == 0
            print(f"[{i+1}/{len(combos)}] {tag}: "
                  f"{'ok' if ok else 'FAIL'} ({time.time()-t0:.0f}s)",
                  flush=True)
            if not ok:
                failures.append(tag)
                with open(os.path.join(args.out, tag + ".err"), "w") as f:
                    f.write(r.stdout[-4000:] + "\n" + r.stderr[-8000:])
        print(f"done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch/--shape required (or --all)"
    rec = run_one(args.arch, args.shape, args.multi_pod)
    from repro import perf_flags
    rec["perf_opts"] = perf_flags.active()
    tag = (f"{args.arch}__{args.shape}__"
           f"{'2x16x16' if args.multi_pod else '16x16'}")
    if args.suffix:
        tag += f"__{args.suffix}"
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "lower_s", "compile_s")},
                     indent=None))
    print("memory:", rec["memory"])
    print("hlo:", rec.get("hlo"))
    print("collectives:", rec["collectives"].get("total_bytes"),
          rec["collectives"].get("counts"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
