"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the paper's full optimized stack — KV-cache engine, half-precision,
optional embedding pruning, dynamic batching and the staged pipeline — over
a synthetic request stream, printing latency/throughput stats.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax

from repro.configs.registry import get_config, get_reduced, list_archs
from repro.core import pruning as PR
from repro.core.engine import InferenceEngine
from repro.core.pipeline import run_pipelined, run_sequential
from repro.core.precision import get_policy
from repro.core.sampling import SamplingParams
from repro.core.tokenizer import FastTokenizer
from repro.data.pipeline import synthetic_corpus
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="unimo-text", choices=list_archs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--policy", default="bf16",
                    choices=["fp32", "bf16", "fp16"])
    ap.add_argument("--kv-dtype", default="auto",
                    choices=["auto", "bf16", "fp16", "int8"],
                    help="KV-cache storage dtype (continuous paged pool): "
                         "auto = compute dtype; int8 stores quantized "
                         "pages + per-entry scales, halving KV bytes per "
                         "token (dense-state layer families keep full "
                         "precision)")
    ap.add_argument("--weights-dtype", default="auto",
                    choices=["auto", "bf16", "fp16", "int8"],
                    help="serve-path weight storage dtype: auto = compute "
                         "dtype; int8 quantizes dense matmul weights "
                         "(attention qkv/out, dense FFN, unembed) to int8 "
                         "codes + per-output-channel scales at load, "
                         "roughly halving bf16 weight bytes read per "
                         "decode step (fused-dequant Pallas matmul on "
                         "TPU; exact jnp fallback elsewhere)")
    ap.add_argument("--no-kv-cache", action="store_true",
                    help="paper baseline mode")
    ap.add_argument("--no-pipeline", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a paged KV cache "
                         "instead of bucket batches")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--steps-per-sync", type=int, default=4)
    ap.add_argument("--max-batched-tokens", type=int, default=None,
                    help="per-iteration token budget of the unified "
                         "scheduler: decode tokens (one per live slot) "
                         "plus chunked-prefill tokens never exceed it, "
                         "so a long prompt cannot stall decode "
                         "(default: engine default, 256)")
    ap.add_argument("--chunked-prefill", default="auto",
                    choices=["auto", "on", "off"],
                    help="unified token-budget iteration with chunked "
                         "prefill (auto = on for layer families that "
                         "support it; off = bucketed whole-prompt "
                         "admission)")
    ap.add_argument("--prefix-cache", default="auto",
                    choices=["auto", "on", "off"],
                    help="radix prefix cache on the continuous path: "
                         "share identical prompt-prefix KV pages across "
                         "requests (auto = on when every layer family "
                         "supports sharing)")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend one shared N-token system prompt to "
                         "every request (demonstrates the prefix cache)")
    ap.add_argument("--spec", default="off",
                    choices=["off", "ngram", "draft"],
                    help="speculative decoding on the continuous path: "
                         "ngram = prompt-lookup drafter (no weights), "
                         "draft = draft-model drafter (self-drafting "
                         "demo).  Distribution preserving; greedy "
                         "streams are bit-identical to --spec off")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="drafted tokens per slot per verify step")
    ap.add_argument("--preemption", default="off",
                    choices=["off", "lru", "priority"],
                    help="overload survivability on the continuous path: "
                         "when admission fails for pages while a slot is "
                         "free, evict a decoding victim (lru = most "
                         "recently admitted, priority = lowest "
                         "Request.priority), offload its KV to the host "
                         "tier and re-queue it — generated tokens "
                         "preserved, greedy streams bit-identical")
    ap.add_argument("--host-kv-bytes", type=int, default=None,
                    help="host-memory KV tier capacity in bytes: holds "
                         "preempted slots' page snapshots and spilled "
                         "prefix-cache leaves (default: no host tier; "
                         "preemption then resumes by re-prefilling)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline in seconds on the serve "
                         "clock: requests still queued past it are "
                         "cancelled with a timed_out outcome")
    ap.add_argument("--debug-audit", action="store_true",
                    help="audit allocator refcounts + host-tier byte "
                         "accounting every serve iteration")
    ap.add_argument("--prune-coverage", type=float, default=None,
                    help="e.g. 0.999 -> prune vocab to that corpus coverage")
    ap.add_argument("--prune-vocab", type=int, default=None, metavar="N",
                    help="prune the embedding/unembedding to the N most "
                         "frequent corpus tokens (hard budget; mutually "
                         "exclusive with --prune-coverage).  The engine "
                         "remaps prompts at admission and unmaps results "
                         "at emit, so callers see original token ids")
    ap.add_argument("--packed", default="auto",
                    choices=["auto", "on", "off"],
                    help="token-packed ragged execution of mixed "
                         "iterations: the whole iteration (decode tokens "
                         "+ prefill chunks) runs as ONE (1, T) dispatch "
                         "(auto = on whenever chunked prefill is on; "
                         "off = legacy decode-micro-step + per-chunk "
                         "dispatches)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a structured serve-loop trace of the "
                         "continuous run: per-iteration timeline, request "
                         "lifecycle spans and scheduler decisions "
                         "(continuous mode only)")
    ap.add_argument("--trace-format", default="jsonl",
                    choices=["jsonl", "perfetto", "both"],
                    help="trace export format: jsonl = one schema-"
                         "versioned event per line; perfetto = Chrome "
                         "trace-event JSON loadable at ui.perfetto.dev; "
                         "both = write <PATH>.jsonl + <PATH>.perfetto.json")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="dump the COMPLETE ServeMetrics — every raw "
                         "counter plus every derived property (host_frac, "
                         "dispatches_per_iter, padded_token_frac, "
                         "prefix_hit_rate, acceptance_rate, ...) — as one "
                         "JSON object (continuous mode only)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()
    if (args.trace_out or args.metrics_json) and not args.continuous:
        raise SystemExit("--trace-out/--metrics-json require --continuous")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.num_codebooks or cfg.num_prefix_embeds:
        raise SystemExit("serve.py drives text archs; audio/VLM backbones "
                         "are exercised via dryrun + smoke tests")
    policy = get_policy(args.policy)
    if args.kv_dtype != "auto":
        policy = dataclasses.replace(policy, kv_dtype=args.kv_dtype)
    if args.weights_dtype != "auto":
        policy = dataclasses.replace(policy,
                                     weights_dtype=args.weights_dtype)
    params = T.init_params(jax.random.PRNGKey(0), cfg, policy)

    corpus = synthetic_corpus(600)
    tok = FastTokenizer.train(corpus, min(cfg.vocab_size, 4000))
    texts = synthetic_corpus(args.requests, seed=7, min_len=4, max_len=40)

    maps = None
    if args.prune_coverage and args.prune_vocab:
        raise SystemExit("--prune-coverage and --prune-vocab are mutually "
                         "exclusive")
    if args.prune_coverage or args.prune_vocab:
        freqs = tok.count_frequencies(corpus)
        params, cfg, maps = PR.prune_model(params, cfg, dict(freqs),
                                           coverage=args.prune_coverage,
                                           max_vocab=args.prune_vocab)
        print(f"pruned vocab -> {cfg.vocab_size}")

    engine = InferenceEngine(cfg, params, policy=policy,
                             max_batch=args.max_batch, max_len=args.max_len,
                             use_kv_cache=not args.no_kv_cache,
                             prune_maps=maps)
    sp = SamplingParams(temperature=args.temperature,
                        top_k=40 if args.temperature > 0 else 0)

    if args.continuous:
        from repro.core.scheduler import Request
        shared = tok.encode(" ".join(synthetic_corpus(
            3, seed=11)))[:args.shared_prefix] if args.shared_prefix else []
        reqs = [Request(uid=i, tokens=shared + tok.encode(t),
                        max_new_tokens=args.max_new_tokens,
                        deadline=args.deadline)
                for i, t in enumerate(texts)]
        prefix = {"auto": None, "on": True, "off": False}[args.prefix_cache]
        chunked = {"auto": None, "on": True,
                   "off": False}[args.chunked_prefill]
        packed = {"auto": None, "on": True, "off": False}[args.packed]
        spec = None
        if args.spec != "off":
            from repro.core.speculative import SpecConfig
            spec = SpecConfig(k=args.spec_k,
                              drafter=("ngram" if args.spec == "ngram"
                                       else "draft_model"))
        tracer = None
        if args.trace_out:
            from repro.core.trace import ServeTracer
            tracer = ServeTracer()
        t0 = time.time()
        done, metrics = engine.serve_continuous(
            reqs, sp, page_size=args.page_size,
            steps_per_sync=args.steps_per_sync, prefix_cache=prefix,
            spec=spec, max_batched_tokens=args.max_batched_tokens,
            chunked_prefill=chunked, packed=packed,
            preemption=args.preemption,
            host_kv_bytes=args.host_kv_bytes,
            debug_audit=args.debug_audit, trace=tracer)
        dt = time.time() - t0
        for r in done[:3]:
            print(f"[{r.uid}] {tok.decode(r.result or [])[:70]!r}")
        if tracer is not None:
            from repro.core.trace import export as trace_export
            for p in trace_export(tracer, args.trace_out,
                                  args.trace_format):
                print(f"trace: {p} ({len(tracer.events)} events, "
                      f"{tracer.dropped} dropped)")
        if args.metrics_json:
            with open(args.metrics_json, "w") as f:
                json.dump({"requests": len(done), "wall_s": round(dt, 3),
                           "tokens_per_s": round(
                               metrics.generated_tokens / dt, 1),
                           "mode": "continuous-paged",
                           **metrics.to_dict()}, f, indent=1)
            print(f"metrics: {args.metrics_json}")
        print(json.dumps({
            "requests": len(done), "wall_s": round(dt, 3),
            "generated_tokens": metrics.generated_tokens,
            "tokens_per_s": round(metrics.generated_tokens / dt, 1),
            "p50_latency_s": round(metrics.percentile_latency(50), 3),
            "p99_latency_s": round(metrics.percentile_latency(99), 3),
            "ttft_p50_s": round(metrics.ttft_p50, 4),
            "ttft_p99_s": round(metrics.ttft_p99, 4),
            "itl_p50_s": round(metrics.itl_p50, 4),
            "itl_p99_s": round(metrics.itl_p99, 4),
            "scheduler": metrics.scheduler,
            "max_batched_tokens": metrics.max_batched_tokens,
            "prefill_chunks": metrics.prefill_chunks,
            "decode_idle_frac": round(metrics.decode_idle_frac, 3),
            "prefill_pad_frac": round(metrics.prefill_pad_frac, 3),
            "dispatches_per_iter": round(metrics.dispatches_per_iter, 3),
            "padded_token_frac": round(metrics.padded_token_frac, 3),
            "host_frac": round(metrics.host_frac, 3),
            "host_s": round(metrics.host_s, 3),
            "device_s": round(metrics.device_s, 3),
            "prefix_hit_rate": round(metrics.prefix_hit_rate, 3),
            "prefix_matched_tokens": metrics.prefix_matched_tokens,
            "pages_shared": metrics.pages_shared,
            "cow_copies": metrics.cow_copies,
            "kv_dtype": metrics.kv_dtype,
            "kv_pool_bytes": metrics.kv_pool_bytes,
            "kv_bytes_per_token": round(metrics.kv_bytes_per_token, 1),
            "weight_dtype": metrics.weight_dtype,
            "weight_bytes": metrics.weight_bytes,
            "weight_bytes_saved": metrics.weight_bytes_saved,
            "host_syncs": metrics.host_syncs,
            "peak_pages_in_use": metrics.peak_pages_in_use,
            "admission_stalls": metrics.admission_stalls,
            "preemptions": metrics.preemptions,
            "resumed": metrics.resumed,
            "offloaded_pages": metrics.offloaded_pages,
            "restored_pages": metrics.restored_pages,
            "host_bytes_peak": metrics.host_bytes_peak,
            "timed_out": metrics.timed_out,
            "deadline_misses": metrics.deadline_misses,
            "outcomes": dict(sorted(metrics.outcome_counts.items())),
            "spec_mode": metrics.spec_mode,
            "acceptance_rate": round(metrics.acceptance_rate, 3),
            "tokens_per_forward": round(metrics.tokens_per_forward, 3),
            "mode": "continuous-paged"}))
        return

    runner = run_sequential if args.no_pipeline else run_pipelined
    t0 = time.time()
    results = runner(texts, tok, engine, max_new_tokens=args.max_new_tokens,
                     sp=sp, max_batch=args.max_batch)
    dt = time.time() - t0

    for r in results[:3]:
        print(f"[{r.uid}] {r.text[:70]!r}")
    st = engine.stats
    print(json.dumps({
        "requests": len(results), "wall_s": round(dt, 3),
        "requests_per_s": round(len(results) / dt, 3),
        "generated_tokens": st.generated_tokens,
        "decode_tok_per_s": round(
            st.generated_tokens / st.decode_s, 1) if st.decode_s else None,
        "prefill_s": round(st.prefill_s, 3),
        "mode": "baseline-nocache" if args.no_kv_cache else "kv-cache",
        "pipelined": not args.no_pipeline}))


if __name__ == "__main__":
    main()
