"""Input ShapeDtypeStruct specs + lowering targets per (arch x input shape).

``input_specs`` returns weak-type-correct, shardable stand-ins — never
allocating device memory — for every model input, including the stub
modality frontends: VLM patch embeddings and audio codec tokens arrive as
precomputed structs of the right shape (the one sanctioned carve-out).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.precision import BF16, MIXED_TRAIN
from repro.models import transformer as T
from repro.sharding import partition as SH
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step

INPUT_SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# archs whose optimizer moments are bf16 in the dry-run (memory; see docs)
LOW_MEM_OPT_THRESHOLD = 1e11


@dataclass
class LoweringTarget:
    """A function + fully-sharded arg structs, ready to .lower()."""
    fn: Callable
    args: tuple
    donate_argnums: tuple = ()
    static_meta: dict = None


def _sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def params_struct(cfg: ModelConfig, mesh, policy, fsdp: bool):
    struct = jax.eval_shape(
        functools.partial(T.init_params, jax.random.PRNGKey(0), cfg,
                          policy=policy))
    specs = SH.param_pspecs(struct, cfg, fsdp=fsdp, mesh=mesh)
    return SH.with_sharding(struct, specs, mesh), specs


def use_fsdp(cfg: ModelConfig) -> bool:
    return cfg.param_counts()["total"] > 2e10


def input_specs(cfg: ModelConfig, shape_name: str, mesh):
    """Returns the batch-input structs for the given input shape."""
    info = INPUT_SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    bspec1 = SH.batch_pspec(mesh, B, extra_dims=1)
    kind = info["kind"]

    def tok_struct(seq):
        if cfg.num_codebooks:
            return _sds((B, seq, cfg.num_codebooks), jnp.int32, mesh,
                        SH.batch_pspec(mesh, B, extra_dims=2))
        return _sds((B, seq), jnp.int32, mesh, bspec1)

    if kind == "train":
        text_S = S - cfg.num_prefix_embeds
        batch = {"tokens": tok_struct(text_S),
                 "labels": tok_struct(text_S),
                 "loss_mask": _sds((B, text_S), jnp.float32, mesh, bspec1)}
        if cfg.num_prefix_embeds:
            batch["prefix_embeds"] = _sds(
                (B, cfg.num_prefix_embeds, cfg.d_model), jnp.bfloat16, mesh,
                SH.batch_pspec(mesh, B, extra_dims=2))
        return batch
    if kind == "prefill":
        return {"tokens": tok_struct(S),
                "lengths": _sds((B,), jnp.int32, mesh, P())}
    # decode: one new token against a cache of S
    return {"tokens": tok_struct(1),
            "lengths": _sds((B,), jnp.int32, mesh, P())}


def cache_specs(cfg: ModelConfig, B: int, max_len: int, mesh,
                dtype=jnp.bfloat16):
    struct = T.cache_struct(cfg, B, max_len, dtype)
    specs = SH.cache_pspecs(struct, mesh, B)
    return SH.with_sharding(struct, specs, mesh)


def make_target(cfg: ModelConfig, shape_name: str, mesh,
                fsdp: Optional[bool] = None) -> LoweringTarget:
    """Build the (fn, sharded arg structs) pair to lower for one combo."""
    info = INPUT_SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    kind = info["kind"]
    fsdp = use_fsdp(cfg) if fsdp is None else fsdp
    meta = {"arch": cfg.name, "shape": shape_name, "kind": kind,
            "batch": B, "seq": S, "fsdp": fsdp}

    if kind == "train":
        from repro import perf_flags
        low_mem = cfg.param_counts()["total"] > LOW_MEM_OPT_THRESHOLD
        policy = MIXED_TRAIN
        if low_mem and perf_flags.flag("bf16_params"):
            # §Perf target B: bf16 parameter storage for >100B archs
            from repro.core.precision import Policy
            policy = Policy(jnp.bfloat16, jnp.bfloat16, jnp.float32)
        factored = low_mem and perf_flags.flag("factored_opt")
        accum = int(perf_flags.flag_value("grad_accum", "1")) \
            if low_mem else 1

        pstruct, pspecs = params_struct(cfg, mesh, policy, fsdp)
        mdt = jnp.bfloat16 if low_mem else jnp.float32
        ostruct = jax.eval_shape(
            functools.partial(OPT.init_state, moment_dtype=mdt,
                              factored=factored), pstruct)
        if factored:
            ospecs = OPT.AdamWState(
                step=P(), mu=None,
                nu=OPT.factored_nu_pspecs(pspecs, pstruct))
        else:
            ospecs = OPT.AdamWState(step=P(),
                                    mu=jax.tree.map(lambda s: s, pspecs),
                                    nu=jax.tree.map(lambda s: s, pspecs))
        ostruct = SH.with_sharding(ostruct, ospecs, mesh)
        batch = input_specs(cfg, shape_name, mesh)
        opt_cfg = OPT.AdamWConfig(factored=factored)
        step = make_train_step(cfg, opt_cfg, policy=policy, remat=True,
                               grad_accum=accum)
        meta.update(low_mem_opt=low_mem, factored=factored,
                    grad_accum=accum, perf_opts=perf_flags.active())
        return LoweringTarget(fn=step, args=(pstruct, ostruct, batch),
                              donate_argnums=(0, 1), static_meta=meta)

    policy = BF16
    pstruct, _ = params_struct(cfg, mesh, policy, fsdp)
    max_len = S
    cstruct = cache_specs(cfg, B, max_len, mesh, policy.compute_dtype)

    if kind == "prefill":
        ins = input_specs(cfg, shape_name, mesh)

        def prefill_fn(params, tokens, lengths, cache):
            return T.forward_prefill(params, cfg, tokens, lengths, cache,
                                     policy=policy, max_len=max_len,
                                     last_only=True)

        return LoweringTarget(
            fn=prefill_fn,
            args=(pstruct, ins["tokens"], ins["lengths"], cstruct),
            donate_argnums=(3,), static_meta=meta)

    ins = input_specs(cfg, shape_name, mesh)

    def decode_fn(params, tokens, cache, lengths):
        return T.forward_decode(params, cfg, tokens, cache, lengths,
                                policy=policy, max_len=max_len)

    return LoweringTarget(
        fn=decode_fn, args=(pstruct, ins["tokens"], cstruct, ins["lengths"]),
        donate_argnums=(2,), static_meta=meta)
