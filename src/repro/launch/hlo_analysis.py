"""Trip-count-aware analysis of compiled SPMD HLO.

XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()`` reports) visits
``while`` bodies exactly once, so for scan-over-layers models it undercounts
FLOPs/bytes by the layer count (verified: scan(10 matmuls) reports the same
flops as 1 matmul).  This module re-derives the three roofline inputs from
``compiled.as_text()`` *with loop trip counts*:

  * ``flops``            — 2*M*N*K per dot, bodies multiplied by the loop
                           bound recovered from the loop-condition constant
  * ``bytes``            — operand + output bytes per instruction (fusion
                           internals excluded: only the fusion call site
                           touches memory), bodies multiplied likewise
  * ``collective_bytes`` — output bytes per collective op, by type

All values are per-chip (the HLO is the per-partition SPMD program).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[\w\[\],{}]+))\s+([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL = re.compile(r"(calls|to_apply|body|condition)=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

# ops whose "output" is a view / no real traffic.  while/conditional/call
# are control flow: their operands alias the callee parameters and the
# callee's instructions are counted (with trip multipliers) instead.
_NO_TRAFFIC = {"get-tuple-element", "tuple", "parameter", "bitcast",
               "constant", "iota", "while", "conditional", "call",
               "after-all", "custom-call"}

# In-place / slicing ops: with buffer donation (the paper's "memory reuse",
# P3) the big operand is aliased, so real HBM traffic is only the moved
# slice.  Counting the full operand would charge a 2.4GB KV cache to every
# single-token decode write.
#   op -> (count_output, skip_first_operand)
_SLICE_OPS = {
    "scatter": (False, True),            # traffic = indices + updates
    "dynamic-update-slice": (False, True),   # traffic = update (+indices)
    "gather": (True, True),              # traffic = indices + gathered out
    "dynamic-slice": (True, True),       # traffic = sliced out
    "slice": (True, True),
    "pad": (True, True),
    "copy": (True, True),                # read once implied by producer
}


def _type_bytes(type_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_text: str) -> List[int]:
    m = _SHAPE.search(type_text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Computation:
    def __init__(self, name):
        self.name = name
        self.shapes: Dict[str, str] = {}     # instr name -> type text
        self.flops = 0.0
        self.bytes = 0.0
        self.bytes_by_op: Dict[str, float] = {}
        self.coll: Dict[str, float] = {}
        self.coll_n: Dict[str, int] = {}
        self.whiles: List[Tuple[str, Optional[str]]] = []
        self.calls: List[str] = []
        self.consts: List[int] = []
        self.n_dots = 0
        # fusion-parameter usage analysis: how many bytes does each
        # parameter of this computation actually move when the computation
        # is a fusion body?  (slice/gather through a param -> only the
        # slice; dynamic-update-slice target -> only the written window)
        self.param_index: Dict[str, int] = {}     # param name -> position
        self.param_sliced: Dict[int, float] = {}  # position -> slice bytes
        self.param_full: set = None               # positions fully read
        self.fusion_calls: List[tuple] = []       # (callee, out_b, [op_b])
        self.alias: Dict[str, str] = {}           # view-op name -> param
        self.ops: Dict[str, str] = {}             # instr name -> op
        self.first_operand: Dict[str, str] = {}
        self.dus_update_bytes: Dict[str, float] = {}
        self.root: Optional[str] = None


def parse_hlo(hlo_text: str):
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        hdr = _COMP_HDR.match(raw)
        if hdr and raw.rstrip().endswith("{"):
            cur = Computation(hdr.group(1))
            cur.param_full = set()
            comps[cur.name] = cur
            if raw.startswith("ENTRY"):
                entry = cur.name
            continue
        if raw.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        cur.consts += [int(c) for c in _CONST.findall(raw)]
        m = _INSTR.match(raw)
        if not m:
            continue
        name, type_text, op = m.groups()
        cur.shapes[name] = type_text
        paren = raw[raw.find(op + "(") + len(op) + 1:]
        arg_text = paren.split(")")[0]
        operands = _OPERAND.findall(arg_text)

        # parameter-usage bookkeeping (for fusion-body analysis) -----------
        cur.ops[name] = op
        if operands:
            cur.first_operand[name] = operands[0]
        if "ROOT" in raw.split("=")[0]:
            cur.root = name
        if op == "dynamic-update-slice" and len(operands) > 1:
            cur.dus_update_bytes[name] = _type_bytes(
                cur.shapes.get(operands[1], ""))
        if op == "scatter" and len(operands) > 2:   # in-place under donation
            cur.dus_update_bytes[name] = _type_bytes(
                cur.shapes.get(operands[1], "")) + _type_bytes(
                cur.shapes.get(operands[2], ""))

        def _resolve(n):
            return cur.alias.get(n, n)

        # `convert` aliases too: an fp32<->bf16 round-trip fused around a
        # cache slice is register traffic, not HBM (XLA CPU legalizes bf16
        # through fp32; TPU would not emit these at all)
        if op == "parameter":
            idx_m = re.search(r"parameter\((\d+)\)", raw)
            if idx_m:
                cur.param_index[name] = int(idx_m.group(1))
        elif op in ("bitcast", "reshape", "transpose", "copy",
                    "convert") and operands:
            src = _resolve(operands[0])
            if src in cur.param_index:
                cur.alias[name] = src          # view chain back to a param
        else:
            slice_rule = _SLICE_OPS.get(op)
            for j, opn in enumerate(operands):
                opn = _resolve(opn)
                if opn not in cur.param_index:
                    continue
                pi = cur.param_index[opn]
                if slice_rule and j == 0:
                    # sliced access: traffic = output (reads) or the
                    # update operand (dynamic-update-slice writes)
                    if op in ("dynamic-update-slice", "scatter"):
                        upd = operands[1] if len(operands) > 1 else None
                        b = _type_bytes(cur.shapes.get(upd, "")) if upd \
                            else 0
                    else:
                        b = _type_bytes(type_text)
                    cur.param_sliced[pi] = cur.param_sliced.get(pi, 0.0) + b
                else:
                    cur.param_full.add(pi)

        # calls / whiles ---------------------------------------------------
        kinds = dict((k, v) for k, v in _CALL.findall(raw))
        if op == "while" and "body" in kinds:
            cur.whiles.append((kinds["body"], kinds.get("condition")))
        elif "calls" in kinds and op == "fusion":
            cur.fusion_calls.append(
                (kinds["calls"], _type_bytes(type_text),
                 [_type_bytes(cur.shapes.get(o, "")) for o in operands]))
            cur.calls.append((kinds["calls"], "fusion"))
        elif "calls" in kinds:
            cur.calls.append((kinds["calls"], "fusion"))
        elif op in ("call", "conditional") and kinds:
            for k, v in kinds.items():
                cur.calls.append((v, "call"))

        # collectives --------------------------------------------------------
        base = None
        for c in COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base:
            b = _type_bytes(type_text)
            cur.coll[base] = cur.coll.get(base, 0) + b
            cur.coll_n[base] = cur.coll_n.get(base, 0) + 1

        # flops (dots) --------------------------------------------------------
        if op == "dot":
            out_elems = 1
            for d in _first_shape_dims(type_text):
                out_elems *= d
            ops_named = _OPERAND.findall(arg_text)
            cm = _CONTRACT.search(raw)
            k_elems = 1
            if cm and ops_named:
                lhs_type = cur.shapes.get(ops_named[0], "")
                lhs_dims = _first_shape_dims(lhs_type)
                for ci in (int(x) for x in cm.group(1).split(",") if x):
                    if ci < len(lhs_dims):
                        k_elems *= lhs_dims[ci]
            cur.flops += 2.0 * out_elems * k_elems
            cur.n_dots += 1

        # bytes ------------------------------------------------------------
        # fusion call-sites are handled in total() via parameter-usage
        # analysis of the fused computation (a fused dynamic-slice of a
        # 1.2GB stacked cache moves one layer's slice, not the whole stack)
        if op not in _NO_TRAFFIC and op != "fusion":
            count_out, skip_first = True, False
            if op in _SLICE_OPS:
                count_out, skip_first = _SLICE_OPS[op]
            b = _type_bytes(type_text) if count_out else 0
            for j, opname in enumerate(operands):
                if skip_first and j == 0:
                    continue
                if opname in cur.shapes:
                    b += _type_bytes(cur.shapes[opname])
            cur.bytes += b
            cur.bytes_by_op[op] = cur.bytes_by_op.get(op, 0.0) + b
    return comps, entry


def _fusion_out_traffic(callee: Optional["Computation"], out_b: float
                        ) -> float:
    """Fusion output traffic: when the fusion root is a dynamic-update-
    slice (in-place cache write under donation), only the written window
    moves — not the whole (often multi-GB stacked) buffer.  The root is
    chased through view/convert ops."""
    if callee is None or callee.root is None:
        return out_b
    name = callee.root
    for _ in range(8):
        op = callee.ops.get(name)
        if op in ("dynamic-update-slice", "scatter"):
            return callee.dus_update_bytes.get(name, out_b)
        if op in ("bitcast", "reshape", "transpose", "copy", "convert"):
            name = callee.first_operand.get(name)
            if name is None:
                return out_b
            continue
        break
    return out_b


def analyze(hlo_text: str) -> dict:
    comps, entry = parse_hlo(hlo_text)

    def trip(cond: Optional[str]) -> int:
        c = comps.get(cond) if cond else None
        if not c or not c.consts:
            return 1
        return max(c.consts)

    memo: Dict[str, dict] = {}

    def total(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        z = {"flops": 0.0, "bytes": 0.0, "n_dots": 0, "by_op": {},
             **{c: 0.0 for c in COLLECTIVES},
             **{c + "#n": 0 for c in COLLECTIVES}}
        memo[name] = z
        c = comps.get(name)
        if c is None or depth > 64:
            return z
        acc = dict(z)
        acc["flops"] = c.flops
        acc["bytes"] = c.bytes
        acc["n_dots"] = c.n_dots
        acc["by_op"] = dict(c.bytes_by_op)

        # fusion call-sites: output + per-parameter actual usage ------------
        fb = 0.0
        for callee_name, out_b, op_bytes in c.fusion_calls:
            callee = comps.get(callee_name)
            b = _fusion_out_traffic(callee, out_b)
            for j, ob in enumerate(op_bytes):
                if callee is None:
                    b += ob
                elif j in callee.param_full:
                    b += ob
                elif j in callee.param_sliced:
                    b += min(callee.param_sliced[j], ob)
                # else: parameter never touched -> no traffic
            fb += b
        acc["bytes"] += fb
        if fb:
            acc["by_op"]["fusion"] = acc["by_op"].get("fusion", 0.0) + fb
        for k, v in c.coll.items():
            acc[k] += v
        for k, v in c.coll_n.items():
            acc[k + "#n"] += v
        for child, kind in c.calls:
            sub = total(child, depth + 1)
            # fusion internals: count flops (dots inside fusions) but not
            # bytes (they never touch HBM; the call site line already did)
            acc["flops"] += sub["flops"]
            acc["n_dots"] += sub["n_dots"]
            for col in COLLECTIVES:
                acc[col] += sub[col]
                acc[col + "#n"] += sub[col + "#n"]
            if kind == "call":
                acc["bytes"] += sub["bytes"]
                for k, v in sub["by_op"].items():
                    acc["by_op"][k] = acc["by_op"].get(k, 0.0) + v
        for body, cond in c.whiles:
            n = trip(cond)
            sub = total(body, depth + 1)
            for k in acc:
                if k == "by_op":
                    for kk, vv in sub["by_op"].items():
                        acc["by_op"][kk] = acc["by_op"].get(kk, 0.0) + vv * n
                else:
                    acc[k] += sub[k] * n
        memo[name] = acc
        return acc

    agg = total(entry) if entry else {}
    by_op = agg.get("by_op", {})
    return {
        "flops": float(agg.get("flops", 0.0)),
        "bytes": float(agg.get("bytes", 0.0)),
        "n_dots": int(agg.get("n_dots", 0)),
        "bytes_by_op": {k: float(v) for k, v in
                        sorted(by_op.items(), key=lambda kv: -kv[1])[:12]},
        "collectives": {
            "total_bytes": float(sum(agg.get(c, 0.0) for c in COLLECTIVES)),
            "per_op_bytes": {c: float(agg.get(c, 0.0)) for c in COLLECTIVES},
            "counts": {c: int(agg.get(c + "#n", 0)) for c in COLLECTIVES},
        },
    }
