"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On the CPU host this trains reduced configs end-to-end; on a real pod the
same script shards params/optimizer per repro.sharding over the production
mesh (--mesh single|multi).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_reduced, list_archs
from repro.core.precision import get_policy
from repro.core.tokenizer import FastTokenizer
from repro.data.pipeline import packed_batches, random_batches, \
    synthetic_corpus
from repro.models import transformer as T
from repro.training import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--policy", default="fp32",
                    choices=["fp32", "bf16", "fp16", "mixed"])
    ap.add_argument("--synthetic-tokens", action="store_true",
                    help="random tokens instead of the Zipf corpus")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    policy = get_policy(args.policy)
    params = T.init_params(jax.random.PRNGKey(0), cfg, policy)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} layers={cfg.num_layers} params={n_params:,}")

    if args.synthetic_tokens or cfg.num_codebooks or cfg.num_prefix_embeds:
        batches = random_batches(cfg.vocab_size, batch_size=args.batch_size,
                                 seq_len=args.seq_len,
                                 num_codebooks=cfg.num_codebooks)
    else:
        corpus = synthetic_corpus(2000)
        tok = FastTokenizer.train(corpus, min(cfg.vocab_size, 4000))
        batches = packed_batches(tok, corpus, batch_size=args.batch_size,
                                 seq_len=args.seq_len)

    opt_cfg = OPT.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps),
                              total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, policy=policy))
    opt_state = OPT.init_state(params)

    t0 = time.time()
    toks_seen = 0
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        if cfg.num_prefix_embeds:
            batch["prefix_embeds"] = 0.02 * jax.random.normal(
                jax.random.PRNGKey(i),
                (args.batch_size, cfg.num_prefix_embeds, cfg.d_model))
        params, opt_state, m = step_fn(params, opt_state, batch)
        toks_seen += args.batch_size * args.seq_len
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(json.dumps({"step": i, "loss": round(float(m["loss"]), 4),
                              "lr": float(m["lr"]),
                              "gnorm": round(float(m["gnorm"]), 3),
                              "tok_per_s": int(toks_seen / max(dt, 1e-9))}))
    if args.checkpoint:
        CKPT.save(args.checkpoint, params, opt_state,
                  meta={"arch": cfg.name, "steps": args.steps})
        print(f"checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
