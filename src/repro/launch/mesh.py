"""Production mesh construction (TPU v5e pods; CPU placeholders in dry-run).

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)                  # 256 chips
MULTI_POD = (2, 16, 16)                # 2 pods x 256 chips

# TPU v5e hardware constants (roofline; per chip)
PEAK_FLOPS_BF16 = 197e12               # FLOP/s
HBM_BW = 819e9                         # B/s
ICI_BW = 50e9                          # B/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) != n:
        if len(devices) < n:
            raise RuntimeError(
                f"need {n} devices, have {len(devices)}; dry-run hosts must "
                "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
                "before any jax import")
        devices = devices[:n]
    import numpy as np
    dev_array = np.asarray(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_host_mesh(model_axis: int = 1) -> jax.sharding.Mesh:
    """Degenerate mesh over the actual local devices (tests/examples)."""
    import numpy as np
    devs = np.asarray(jax.devices())
    n = len(devs)
    data = n // model_axis
    return jax.sharding.Mesh(devs[:data * model_axis].reshape(
        data, model_axis), ("data", "model"))
