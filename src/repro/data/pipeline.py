"""Data pipeline: synthetic corpus generation + tokenizing batcher.

No external datasets are available offline, so the corpus is a synthetic
Zipf-distributed "marketing material" stream whose skewed token frequencies
are exactly the regime the paper's vocabulary pruning (P2) exploits.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

import numpy as np

from repro.core.tokenizer import BOS, EOS, PAD, FastTokenizer

_WORDS = [
    "brand", "market", "click", "user", "offer", "sale", "quality",
    "product", "smart", "fast", "trust", "deal", "value", "shop", "tech",
    "cloud", "model", "learn", "data", "search", "video", "music", "photo",
    "travel", "home", "auto", "game", "news", "health", "food", "style",
    "price", "best", "new", "top", "win", "free", "plus", "pro", "max",
]


def synthetic_corpus(num_lines: int, *, seed: int = 0,
                     min_len: int = 4, max_len: int = 24) -> List[str]:
    """Zipf-weighted word salad; rank-frequency matches real text well
    enough for pruning/coverage experiments."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, len(_WORDS) + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)
    lines = []
    for _ in range(num_lines):
        n = int(rng.integers(min_len, max_len + 1))
        idx = rng.choice(len(_WORDS), size=n, p=probs)
        lines.append(" ".join(_WORDS[i] for i in idx))
    return lines


def token_stream(tokenizer: FastTokenizer, corpus: List[str]
                 ) -> Iterator[int]:
    for line in corpus:
        yield from tokenizer.encode(line, bos=True, eos=True)


def packed_batches(tokenizer: FastTokenizer, corpus: List[str], *,
                   batch_size: int, seq_len: int,
                   repeat: bool = True, seed: int = 0
                   ) -> Iterator[dict]:
    """Dense packed LM batches: {"tokens": (B,S), "labels": (B,S),
    "loss_mask": (B,S)} — labels are next-token shifted."""
    need = batch_size * (seq_len + 1)
    buf: List[int] = []
    epoch = 0
    while True:
        for t in token_stream(tokenizer, corpus):
            buf.append(t)
            if len(buf) >= need:
                arr = np.asarray(buf[:need], np.int32).reshape(
                    batch_size, seq_len + 1)
                buf = buf[need:]
                yield {"tokens": arr[:, :-1],
                       "labels": arr[:, 1:].astype(np.int32),
                       "loss_mask": (arr[:, 1:] != PAD).astype(np.float32)}
        epoch += 1
        if not repeat:
            return


def random_batches(vocab_size: int, *, batch_size: int, seq_len: int,
                   num_codebooks: int = 0, seed: int = 0) -> Iterator[dict]:
    """Uniform-random token batches (for smoke tests / shape checks)."""
    rng = np.random.default_rng(seed)
    while True:
        shape = ((batch_size, seq_len, num_codebooks) if num_codebooks
                 else (batch_size, seq_len))
        toks = rng.integers(4, vocab_size, size=shape, dtype=np.int32)
        labels = rng.integers(4, vocab_size, size=shape, dtype=np.int32)
        yield {"tokens": toks, "labels": labels,
               "loss_mask": np.ones(shape[:2], np.float32)}
