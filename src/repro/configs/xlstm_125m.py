"""xlstm-125m [ssm] — 12L d_model=768 4H d_ff=0 vocab=50304; alternating
sLSTM + mLSTM blocks (1:1), attention-free.  [arXiv:2405.04517]

Blocks carry their own up/down projections (d_ff=0 -> no separate FFN).
Recurrent state is the KV-cache generalization: O(1) memory per stream, so
long_500k decode runs natively.
"""
from repro.configs.base import (MLSTM, NO_FFN, SLSTM, LayerSpec, ModelConfig,
                                patterned_stacks)

ARCH = "xlstm-125m"

_PATTERN = (LayerSpec(mixer=MLSTM, ffn=NO_FFN),
            LayerSpec(mixer=SLSTM, ffn=NO_FFN))


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="ssm", source="arXiv:2405.04517",
        d_model=768, num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
        stacks=patterned_stacks(12, _PATTERN),
        norm="layernorm", pos_emb="none", tie_embeddings=True,
        native_context=1 << 20,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=128, num_heads=2, num_kv_heads=2, vocab_size=512,
        stacks=patterned_stacks(2, _PATTERN), native_context=1 << 20)
