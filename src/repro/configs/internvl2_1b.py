"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655; InternViT vision encoder + Qwen2-0.5B-family LM backbone.
[arXiv:2404.16821]

The InternViT encoder + MLP projector is a STUB per the brief:
``input_specs`` provides 256 precomputed patch embeddings of width d_model
which are prepended to the text tokens.
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_stack

ARCH = "internvl2-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="vlm", source="arXiv:2404.16821",
        d_model=896, num_heads=14, num_kv_heads=2, head_dim=64,
        d_ff=4864, vocab_size=151655, num_prefix_embeds=256,
        stacks=uniform_stack(24, LayerSpec()),
        rope_theta=1e6, activation="swiglu", norm="rmsnorm",
        tie_embeddings=True, native_context=32768,
        long_context_override=8192,   # beyond-paper SWA variant for 500k
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=512, num_prefix_embeds=16,
        stacks=uniform_stack(2, LayerSpec()),
        native_context=256, long_context_override=None)
