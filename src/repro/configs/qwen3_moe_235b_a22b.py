"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
d_ff=1536(expert) vocab=151936, MoE 128e top-8, qk-norm.
[hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import (MOE_FFN, LayerSpec, ModelConfig, MoEConfig,
                                uniform_stack)

ARCH = "qwen3-moe-235b-a22b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="moe", source="hf:Qwen/Qwen3-30B-A3B",
        d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
        d_ff=0, vocab_size=151936,
        stacks=uniform_stack(94, LayerSpec(ffn=MOE_FFN)),
        moe=MoEConfig(num_experts=128, top_k=8, num_shared_experts=0,
                      d_ff_expert=1536, capacity_factor=1.25),
        qk_norm=True, rope_theta=1e6, activation="swiglu", norm="rmsnorm",
        tie_embeddings=False, native_context=32768,
        long_context_override=8192,   # beyond-paper SWA variant for 500k
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=256, num_heads=8, num_kv_heads=2, head_dim=32,
        vocab_size=512, stacks=uniform_stack(2, LayerSpec(ffn=MOE_FFN)),
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=0,
                      d_ff_expert=128, capacity_factor=1.5),
        native_context=256, long_context_override=None)
