"""unimo-text — the paper's own model (§3.1): 24-layer transformer,
learned position embeddings (512 x 1024), vocab 12800.  This is the config
the Table-1 reproduction benchmark runs, including the paper's exact
position-embedding trim (512 -> 128) and vocabulary pruning.
[paper: AIGC Inference Performance Optimization Competition solution]"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_stack

ARCH = "unimo-text"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", source="paper §3.1 (UNIMO-text)",
        d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
        d_ff=4096, vocab_size=12800,
        stacks=uniform_stack(24, LayerSpec()),
        pos_emb="learned", max_seq_len=512,
        activation="gelu", norm="layernorm", tie_embeddings=True,
        native_context=512,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=64, d_ff=512,
        vocab_size=1600, stacks=uniform_stack(2, LayerSpec()),
        max_seq_len=128, native_context=128)
