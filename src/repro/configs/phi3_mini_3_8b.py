"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064; RoPE SwiGLU GQA.  [arXiv:2404.14219]"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_stack

ARCH = "phi3-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", source="arXiv:2404.14219",
        d_model=3072, num_heads=32, num_kv_heads=32, head_dim=96,
        d_ff=8192, vocab_size=32064,
        stacks=uniform_stack(32, LayerSpec()),
        rope_theta=10000.0, activation="swiglu", norm="rmsnorm",
        tie_embeddings=False, native_context=4096,
        long_context_override=8192,   # beyond-paper SWA variant for 500k
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=64, d_ff=512,
        vocab_size=512, stacks=uniform_stack(2, LayerSpec()),
        native_context=256, long_context_override=None)
