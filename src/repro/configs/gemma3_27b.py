"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local(1024):global, 128k context, dual rope theta,
qk-norm, sandwich norms.  [hf:google/gemma-3-1b-pt]"""
from repro.configs.base import LayerSpec, ModelConfig, patterned_stacks

ARCH = "gemma3-27b"

_PATTERN = tuple([LayerSpec(window=1024)] * 5 + [LayerSpec(window=None)])


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", source="hf:google/gemma-3-1b-pt",
        d_model=5376, num_heads=32, num_kv_heads=16, head_dim=128,
        d_ff=21504, vocab_size=262144,
        stacks=patterned_stacks(62, _PATTERN),
        qk_norm=True, sandwich_norm=True, embed_scale=True,
        rope_theta=1e6, rope_theta_local=10000.0,
        activation="geglu", norm="rmsnorm", tie_embeddings=True,
        native_context=131072,
        # native 5:1 sliding-window -> long_500k runs without override
    )


def reduced() -> ModelConfig:
    pattern = tuple([LayerSpec(window=64)] * 1 + [LayerSpec(window=None)])
    return config().replace(
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512,
        vocab_size=512, stacks=patterned_stacks(2, pattern),
        native_context=256)
