"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES = {
    "qwen3-4b": "repro.configs.qwen3_4b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "unimo-text": "repro.configs.unimo_text",
}

ASSIGNED: List[str] = [a for a in _MODULES if a != "unimo-text"]


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).config()


def get_reduced(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).reduced()


def list_archs() -> List[str]:
    return list(_MODULES)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in _MODULES}
