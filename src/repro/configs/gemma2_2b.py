"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000; alternating local(4096)/global attention, logit softcaps,
sandwich norms.  [arXiv:2408.00118]"""
from repro.configs.base import LayerSpec, ModelConfig, patterned_stacks

ARCH = "gemma2-2b"

_PATTERN = (LayerSpec(window=4096), LayerSpec(window=None))


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", source="arXiv:2408.00118",
        d_model=2304, num_heads=8, num_kv_heads=4, head_dim=256,
        d_ff=9216, vocab_size=256000,
        stacks=patterned_stacks(26, _PATTERN),
        attn_softcap=50.0, final_softcap=30.0,
        sandwich_norm=True, embed_scale=True,
        attn_scale=256 ** -0.5,       # query_pre_attn_scalar = 256
        rope_theta=10000.0, activation="geglu", norm="rmsnorm",
        tie_embeddings=True, native_context=8192,
        # native alternating sliding-window -> long_500k runs w/o override
    )


def reduced() -> ModelConfig:
    pattern = (LayerSpec(window=64), LayerSpec(window=None))
    return config().replace(
        d_model=256, num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512,
        vocab_size=512, stacks=patterned_stacks(2, pattern),
        attn_scale=None, native_context=256)
