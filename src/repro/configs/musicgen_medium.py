"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens (4 codebooks, delay pattern
handled at the data level).  [arXiv:2306.05284]

The EnCodec conv codec frontend is a STUB per the brief: ``input_specs``
provides token ids per codebook; conditioning operates unconditionally
(MusicGen's text-free mode).  Sinusoidal positions as in the paper.
"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_stack

ARCH = "musicgen-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="audio", source="arXiv:2306.05284",
        d_model=1536, num_heads=24, num_kv_heads=24, head_dim=64,
        d_ff=6144, vocab_size=2048, num_codebooks=4,
        stacks=uniform_stack(48, LayerSpec()),
        activation="gelu", norm="layernorm", pos_emb="sinusoidal",
        tie_embeddings=True, native_context=16384,
        long_context_override=8192,   # beyond-paper SWA variant for 500k
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=192, num_heads=6, num_kv_heads=6, head_dim=32, d_ff=384,
        vocab_size=256, num_codebooks=2,
        stacks=uniform_stack(2, LayerSpec()),
        native_context=256, long_context_override=None)
