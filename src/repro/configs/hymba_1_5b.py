"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attn+mamba heads.  [arXiv:2411.13676]

Layer layout follows the paper's 3-global-attention pattern (first /
middle / last layers global, the rest sliding-window 1024), every layer a
parallel attention+SSM hybrid.
"""
from repro.configs.base import (HYBRID, LayerSpec, ModelConfig, SSMConfig,
                                Stack)

ARCH = "hymba-1.5b"

_G = LayerSpec(mixer=HYBRID, window=None)
_W = LayerSpec(mixer=HYBRID, window=1024)


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="hybrid", source="arXiv:2411.13676",
        d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
        d_ff=5504, vocab_size=32001,
        stacks=(Stack((_G,), 1), Stack((_W,), 14), Stack((_G,), 1),
                Stack((_W,), 15), Stack((_G,), 1)),
        ssm=SSMConfig(state_size=16, conv_size=4, expand=2, num_ssm_heads=25),
        activation="swiglu", norm="rmsnorm", tie_embeddings=True,
        native_context=8192,
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=160, num_heads=5, num_kv_heads=1, head_dim=32, d_ff=320,
        vocab_size=512,
        stacks=(Stack((_G,), 1), Stack((LayerSpec(mixer=HYBRID, window=64),),
                                       1)),
        ssm=SSMConfig(state_size=8, conv_size=4, expand=2, num_ssm_heads=5),
        native_context=256)
