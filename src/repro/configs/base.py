"""Model/config system.

A model is described by a :class:`ModelConfig`, which is a sequence of
*stacks*.  Each stack is a repeating *pattern* of :class:`LayerSpec`s; the
pattern is unrolled inside a ``lax.scan`` body and the scan runs over the
repeats.  This keeps the HLO for a 62-layer model the size of a
``pattern_len``-layer model, which matters both for compile time on the
single-core dry-run host and for real-TPU compile latency.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer specification
# ---------------------------------------------------------------------------

# mixer kinds
ATTN = "attn"          # softmax attention (GQA), optional sliding window
MLA = "mla"            # DeepSeek multi-head latent attention
MLSTM = "mlstm"        # xLSTM matrix-memory block
SLSTM = "slstm"        # xLSTM scalar-memory block
HYBRID = "hybrid"      # Hymba parallel attention + SSM heads

# ffn kinds
DENSE_FFN = "dense"
MOE_FFN = "moe"
NO_FFN = "none"        # xLSTM blocks carry their own projection; no FFN


@dataclass(frozen=True)
class LayerSpec:
    """One layer position inside a stack pattern."""

    mixer: str = ATTN
    ffn: str = DENSE_FFN
    window: Optional[int] = None  # sliding-window size; None = global attention

    def __post_init__(self):
        assert self.mixer in (ATTN, MLA, MLSTM, SLSTM, HYBRID), self.mixer
        assert self.ffn in (DENSE_FFN, MOE_FFN, NO_FFN), self.ffn


@dataclass(frozen=True)
class Stack:
    """``repeats`` x ``pattern`` layers, scanned over ``repeats``."""

    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    num_shared_experts: int = 0
    d_ff_expert: int = 0            # per-expert FFN width
    capacity_factor: float = 1.25
    router_aux_coef: float = 1e-2


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_size: int = 16
    conv_size: int = 4
    expand: int = 2                 # d_inner = expand * d_model (per-SSM-branch)
    num_ssm_heads: int = 0          # hybrid: SSM heads in parallel with attn heads


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                # citation for the assigned config

    d_model: int = 512
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0               # 0 -> d_model // num_heads
    d_ff: int = 2048
    vocab_size: int = 32000
    stacks: Tuple[Stack, ...] = ()

    # attention details
    qk_norm: bool = False
    attn_softcap: Optional[float] = None      # gemma2: 50.0
    final_softcap: Optional[float] = None     # gemma2: 30.0
    rope_theta: float = 10000.0
    rope_theta_local: Optional[float] = None  # gemma3: 10k local vs 1M global
    pos_emb: str = "rope"           # rope | learned | none
    max_seq_len: int = 1 << 19      # for learned positions / rope tables
    attn_scale: Optional[float] = None        # None -> 1/sqrt(head_dim)

    # block structure
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    sandwich_norm: bool = False     # gemma2/3: post-norm after mixer/ffn as well
    activation: str = "swiglu"      # swiglu | geglu | gelu
    tie_embeddings: bool = True
    embed_scale: bool = False       # gemma: scale embeddings by sqrt(d_model)

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # multimodal stub frontend: number of prefix embeddings prepended to text
    num_prefix_embeds: int = 0      # vlm: image patches; audio: conditioning frames
    num_codebooks: int = 0          # audio: parallel codec streams (musicgen: 4)

    # DeepSeek multi-token prediction
    mtp: bool = False

    # long-context: window applied to *global* layers when serving >
    # native_context tokens (beyond-paper sliding-window override)
    long_context_override: Optional[int] = None
    native_context: int = 1 << 17

    def __post_init__(self):
        if not self.stacks:
            object.__setattr__(
                self, "stacks", (Stack((LayerSpec(),), 2),))

    # -- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_layers(self) -> int:
        return sum(s.num_layers for s in self.stacks)

    @property
    def is_subquadratic(self) -> bool:
        """True if every layer is windowed / recurrent (native long-context)."""
        for s in self.stacks:
            for spec in s.pattern:
                if spec.mixer in (ATTN, MLA, HYBRID) and spec.window is None:
                    if spec.mixer == HYBRID:
                        continue  # hybrid SSM branch keeps it linear-ish
                    return False
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter count (for roofline MODEL_FLOPS) -------------------------
    def param_counts(self) -> dict:
        """Returns {'total': N, 'active': N_active, 'embed': ...}."""
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        embed = self.vocab_size * d
        if not self.tie_embeddings:
            embed *= 2
        if self.pos_emb == "learned":
            embed += self.max_seq_len * d
        if self.num_codebooks:
            embed += self.num_codebooks * self.vocab_size * d

        def attn_params():
            return d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d

        def mla_params():
            m = self.mla
            p = d * m.q_lora_rank
            p += m.q_lora_rank * nq * (m.nope_head_dim + m.rope_head_dim)
            p += d * (m.kv_lora_rank + m.rope_head_dim)
            p += m.kv_lora_rank * nq * (m.nope_head_dim + m.v_head_dim)
            p += nq * m.v_head_dim * d
            return p

        def ffn_params(width):
            mult = 3 if self.activation in ("swiglu", "geglu") else 2
            return mult * d * width

        def ssm_inner():
            s = self.ssm
            di = s.expand * d
            # in-proj (x,z), conv, dt/B/C proj, out-proj
            return 2 * d * di + s.conv_size * di + di * (2 * s.state_size + 1) + di * d

        def mlstm_params():
            di = 2 * d
            return d * di * 2 + 3 * di * hd * 0 + d * di + 4 * d  # approx: qkv+gates+out
        total = embed
        active = embed
        for st in self.stacks:
            for spec in st.pattern:
                lt = la = 0
                if spec.mixer == ATTN:
                    lt = la = attn_params()
                elif spec.mixer == MLA:
                    lt = la = mla_params()
                elif spec.mixer == MLSTM:
                    di = 2 * d
                    lt = la = 2 * d * di + di * d + 3 * d * di  # qkv+gates+updown
                elif spec.mixer == SLSTM:
                    lt = la = 8 * d * d // 1  # 4 gates x (W + R) per head approx
                elif spec.mixer == HYBRID:
                    lt = la = attn_params() + ssm_inner()
                if spec.ffn == DENSE_FFN:
                    lt += ffn_params(self.d_ff)
                    la += ffn_params(self.d_ff)
                elif spec.ffn == MOE_FFN:
                    m = self.moe
                    router = d * m.num_experts
                    shared = m.num_shared_experts * ffn_params(m.d_ff_expert)
                    lt += router + shared + m.num_experts * ffn_params(m.d_ff_expert)
                    la += router + shared + m.top_k * ffn_params(m.d_ff_expert)
                lt += 2 * d  # norms
                la += 2 * d
                total += lt * st.repeats
                active += la * st.repeats
        return {"total": total, "active": active, "embed": embed}


def uniform_stack(n_layers: int, spec: LayerSpec) -> Tuple[Stack, ...]:
    return (Stack((spec,), n_layers),)


def patterned_stacks(n_layers: int, pattern: Sequence[LayerSpec]) -> Tuple[Stack, ...]:
    """Repeat ``pattern`` as many whole times as fits; remainder becomes a
    second stack of single-layer repeats (prefix of the pattern)."""
    p = len(pattern)
    reps, rem = divmod(n_layers, p)
    stacks = []
    if reps:
        stacks.append(Stack(tuple(pattern), reps))
    for i in range(rem):
        stacks.append(Stack((pattern[i],), 1))
    return tuple(stacks)
