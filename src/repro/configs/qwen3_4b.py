"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936; qk_norm, GQA.  [hf:Qwen/Qwen3-8B]"""
from repro.configs.base import LayerSpec, ModelConfig, uniform_stack

ARCH = "qwen3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH, family="dense", source="hf:Qwen/Qwen3-8B",
        d_model=2560, num_heads=32, num_kv_heads=8, head_dim=128,
        d_ff=9728, vocab_size=151936,
        stacks=uniform_stack(36, LayerSpec()),
        qk_norm=True, rope_theta=1e6, activation="swiglu", norm="rmsnorm",
        tie_embeddings=True, native_context=32768,
        long_context_override=8192,   # beyond-paper SWA variant for 500k
    )


def reduced() -> ModelConfig:
    return config().replace(
        d_model=256, num_heads=8, num_kv_heads=2, head_dim=32, d_ff=512,
        vocab_size=512, stacks=uniform_stack(2, LayerSpec()),
        native_context=256, long_context_override=None)
