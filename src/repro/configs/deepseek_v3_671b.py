"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff=2048(expert)
vocab=129280, MoE 256e top-8, MLA, 1 shared + 256 routed, MTP.
[arXiv:2412.19437]

First 3 layers are dense (d_ff=18432) with MLA attention; the remaining 58
are MLA + 256-expert top-8 sigmoid-routed MoE with one shared expert.
"""
from repro.configs.base import (DENSE_FFN, MLA, MOE_FFN, LayerSpec,
                                MLAConfig, ModelConfig, MoEConfig, Stack)

ARCH = "deepseek-v3-671b"


def config() -> ModelConfig:
    dense = LayerSpec(mixer=MLA, ffn=DENSE_FFN)
    moe = LayerSpec(mixer=MLA, ffn=MOE_FFN)
    return ModelConfig(
        name=ARCH, family="moe", source="arXiv:2412.19437",
        d_model=7168, num_heads=128, num_kv_heads=128, head_dim=192,
        d_ff=18432, vocab_size=129280,
        stacks=(Stack((dense,), 3), Stack((moe,), 58)),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
                      nope_head_dim=128, v_head_dim=128),
        moe=MoEConfig(num_experts=256, top_k=8, num_shared_experts=1,
                      d_ff_expert=2048, capacity_factor=1.25),
        mtp=True, rope_theta=10000.0, activation="swiglu", norm="rmsnorm",
        tie_embeddings=False, native_context=131072,
        long_context_override=8192,   # beyond-paper SWA variant for 500k
    )


def reduced() -> ModelConfig:
    dense = LayerSpec(mixer=MLA, ffn=DENSE_FFN)
    moe = LayerSpec(mixer=MLA, ffn=MOE_FFN)
    return config().replace(
        d_model=256, num_heads=4, num_kv_heads=4, head_dim=48, d_ff=512,
        vocab_size=512,
        stacks=(Stack((dense,), 1), Stack((moe,), 1)),
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, rope_head_dim=16,
                      nope_head_dim=32, v_head_dim=32),
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      d_ff_expert=128, capacity_factor=1.5),
        native_context=256, long_context_override=None)
