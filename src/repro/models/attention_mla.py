"""DeepSeek-V3 Multi-head Latent Attention (MLA).

Two execution forms:

  * **naive** (train / prefill): decompress the latent into per-head K/V and
    run standard attention — simple, differentiable.
  * **absorbed** (decode): the paper pillar P1's KV-cache insight in its MLA
    form.  Only the compressed latent ``c_kv`` (kv_lora_rank) plus the
    shared rotated key ``k_rope`` are cached; at decode time the query is
    *absorbed* through the decompression matrices so attention runs directly
    in latent space.  Cache bytes per token: rank+rope = 576 floats instead
    of 2*128*(128+128) — the compression that makes 128-head decode at 32k
    context feasible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import kv_cache as KV
from repro.models import layers as L


def mla_init(rng, cfg: ModelConfig):
    m, d, H = cfg.mla, cfg.d_model, cfg.num_heads
    ks = jax.random.split(rng, 7)
    qh = m.nope_head_dim + m.rope_head_dim
    return {
        "wdq": L.dense_init(ks[0], d, m.q_lora_rank),
        "q_norm": {"w": jnp.zeros((m.q_lora_rank,))},
        "wuq": L.dense_init(ks[1], m.q_lora_rank, H * qh),
        "wdkv": L.dense_init(ks[2], d, m.kv_lora_rank),
        "kv_norm": {"w": jnp.zeros((m.kv_lora_rank,))},
        "wukv": L.dense_init(ks[3], m.kv_lora_rank,
                             H * (m.nope_head_dim + m.v_head_dim)),
        "wkr": L.dense_init(ks[4], d, m.rope_head_dim),
        "wo": L.dense_init(ks[5], H * m.v_head_dim, d),
    }


def _project(cfg, p, x, positions):
    """Common projections. Returns q_nope, q_rope, c_kv(normed), k_rope."""
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    cq = L.rmsnorm(x @ p["wdq"].astype(x.dtype), p["q_norm"]["w"])
    q = (cq @ p["wuq"].astype(x.dtype)).reshape(
        B, S, H, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = q[..., :m.nope_head_dim], q[..., m.nope_head_dim:]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)
    ckv = L.rmsnorm(x @ p["wdkv"].astype(x.dtype), p["kv_norm"]["w"])
    kr = L.rope((x @ p["wkr"].astype(x.dtype))[:, :, None, :],
                positions, cfg.rope_theta)[:, :, 0, :]            # (B,S,rope)
    return q_nope, q_rope, ckv, kr


def mla_scale(cfg: ModelConfig) -> float:
    m = cfg.mla
    return (m.nope_head_dim + m.rope_head_dim) ** -0.5


def mla_full(cfg: ModelConfig, p, x, positions, k_pos, window=None):
    """Naive form over the in-context tokens (train/prefill).

    Returns (out (B,S,d), {"ckv": ..., "kr": ...} to cache).
    """
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    q_nope, q_rope, ckv, kr = _project(cfg, p, x, positions)
    kv = (ckv @ p["wukv"].astype(x.dtype)).reshape(
        B, S, H, m.nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.nope_head_dim], kv[..., m.nope_head_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, m.rope_head_dim))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    ctx = L.mha_attention(q, k, v, positions, k_pos, window=window,
                          scale=mla_scale(cfg), attn_softcap=None)
    out = ctx.reshape(B, S, H * m.v_head_dim) @ p["wo"].astype(x.dtype)
    return out, {"ckv": ckv, "kr": kr}


def mla_prefill_cached(cfg: ModelConfig, p, x, cache, positions, cache_pos,
                       window=None):
    """Prefill continuing from a pre-filled latent cache (prefix caching):
    write the new latents, then decompress the *whole* cache and attend."""
    m, H = cfg.mla, cfg.num_heads
    B, S, _ = x.shape
    q_nope, q_rope, ckv, kr = _project(cfg, p, x, positions)
    cache = KV.write_prefill(cache, {"ckv": ckv, "kr": kr}, cache_pos)
    ckv_all = cache["ckv"].astype(x.dtype)                        # (B,Sc,r)
    kr_all = cache["kr"].astype(x.dtype)
    Sc = ckv_all.shape[1]
    kv = (ckv_all @ p["wukv"].astype(x.dtype)).reshape(
        B, Sc, H, m.nope_head_dim + m.v_head_dim)
    k_nope, v = kv[..., :m.nope_head_dim], kv[..., m.nope_head_dim:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all[:, :, None, :],
                                  (B, Sc, H, m.rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    ctx = L.mha_attention(q, k, v, positions, cache["pos"], window=window,
                          scale=mla_scale(cfg), attn_softcap=None)
    out = ctx.reshape(B, S, H * m.v_head_dim) @ p["wo"].astype(x.dtype)
    return out, cache


def mla_decode(cfg: ModelConfig, p, x, cache, lengths):
    """Absorbed-form single-token decode against the latent cache."""
    m, H = cfg.mla, cfg.num_heads
    B = x.shape[0]
    positions = lengths[:, None]
    q_nope, q_rope, ckv_new, kr_new = _project(cfg, p, x, positions)
    cache = KV.write_decode(cache, {"ckv": ckv_new, "kr": kr_new}, lengths)

    wukv = p["wukv"].astype(jnp.float32).reshape(
        m.kv_lora_rank, H, m.nope_head_dim + m.v_head_dim)
    wk = wukv[..., :m.nope_head_dim]                              # (r,H,nope)
    wv = wukv[..., m.nope_head_dim:]                              # (r,H,v)

    # absorb q through the key-decompression: (B,1,H,nope)x(r,H,nope)->(B,H,r)
    from repro import perf_flags
    half = perf_flags.flag("attn_bf16")   # §Perf: no fp32 copy of the cache
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32), wk)
    ckv_f = cache["ckv"] if half else cache["ckv"].astype(jnp.float32)
    kr_f = cache["kr"] if half else cache["kr"].astype(jnp.float32)
    q_lat_s = q_lat.astype(ckv_f.dtype)
    q_rope_s = q_rope[:, 0].astype(kr_f.dtype)
    scores = (jnp.einsum("bhr,bsr->bhs", q_lat_s, ckv_f,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bhd,bsd->bhs", q_rope_s, kr_f,
                           preferred_element_type=jnp.float32)) \
        * mla_scale(cfg)
    mask = KV.cache_mask(cache["pos"], positions, None)[:, 0]     # (B,Sc)
    scores = jnp.where(mask[:, None, :], scores, L.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_lat = jnp.einsum("bhs,bsr->bhr", probs.astype(ckv_f.dtype), ckv_f,
                         preferred_element_type=jnp.float32)      # (B,H,r)
    ctx = jnp.einsum("bhr,rhd->bhd", ctx_lat, wv)                 # (B,H,v)
    out = (ctx.reshape(B, 1 * H * m.v_head_dim).astype(x.dtype)
           .reshape(B, H * m.v_head_dim) @ p["wo"].astype(x.dtype))
    return out[:, None, :], cache
