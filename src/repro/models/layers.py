"""Core building blocks, pure-functional JAX (params are nested dicts).

Everything here is written against the *reference* jnp path; the Pallas
kernels in ``repro.kernels`` implement the hot paths (flash attention,
decode attention, rmsnorm) and are swapped in via ``repro.kernels.ops``
runtime mode without changing model code.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, in_dim: int, out_dim: int, dtype=jnp.float32):
    scale = in_dim ** -0.5
    return (jax.random.normal(rng, (in_dim, out_dim), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(rng, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.normal(rng, (vocab, dim), dtype=jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Dense matmul (weight-quantization aware)
# ---------------------------------------------------------------------------


def dense_matmul(x, w):
    """``x @ w`` for a serve-path dense weight.

    ``w`` is either a plain (..., in, out) array — cast to ``x.dtype``
    at point of use, the historical path — or an int8 weight record
    ``{"q": int8, "s": fp32}`` produced by ``precision.quantize_weights``
    (per-output-channel absmax; ``lax.scan`` over stacked weights slices
    the record's arrays per repeat, so call sites see 2-D codes).
    Quantized records dispatch to the fused-dequant Pallas kernel and
    fall back to the jnp oracle (identical math, fp32 accumulate-then-
    scale) when kernels are off/unsupported — CPU tier-1 stays exact.
    """
    if not isinstance(w, dict):
        return x @ w.astype(x.dtype)
    from repro.kernels import ops as kops
    out = kops.maybe_quant_matmul(x, w["q"], w["s"])
    if out is None:
        from repro.kernels import ref as kref
        out = kref.quant_matmul_ref(x, w["q"], w["s"])
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    return out.astype(dt)


def layernorm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * w + b
    return out.astype(dt)


def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"w": jnp.zeros((d,))}
    return {"w": jnp.ones((d,)), "b": jnp.zeros((d,))}


def apply_norm(cfg: ModelConfig, p, x):
    if "b" in p:
        return layernorm(x, p["w"], p["b"])
    return rmsnorm(x, p["w"])


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

NEG_INF = -2.3819763e38  # most-negative bf16-representable; safe in fp32 too


def causal_mask(q_pos, k_pos, window: Optional[int] = None):
    """Boolean mask (..., Sq, Sk): True = attend.

    q_pos/k_pos: integer position arrays broadcastable to (..., Sq) / (..., Sk).
    """
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


# ---------------------------------------------------------------------------
# Attention (GQA) — reference / chunked(flash-at-HLO-level) / Pallas dispatch
# ---------------------------------------------------------------------------

# above this many keys the chunked (never-materialize-S^2) path is used, so
# prefill_32k / long_500k graphs stay within per-device HBM.
CHUNK_THRESHOLD = 2048
KV_BLOCK = 1024


def mha_attention(q, k, v, q_pos, k_pos, *, window: Optional[int],
                  scale: float, attn_softcap: Optional[float] = None):
    """Causal GQA attention driven by absolute positions.

    q: (B,Sq,Hq,D), k/v: (B,Sk,Hkv,D), q_pos: (B,Sq), k_pos: (B,Sk) with
    -1 marking invalid (empty cache slot / padding) keys.

    Dispatch order: Pallas flash kernel (if kernel mode enabled and shape
    qualifies) -> chunked lax.scan flash (large Sk) -> naive reference.
    All three compute identical math.
    """
    from repro.kernels import ops as kops
    out = kops.maybe_flash_attention(q, k, v, q_pos, k_pos, window=window,
                                     scale=scale, attn_softcap=attn_softcap)
    if out is not None:
        return out
    # chunked only for multi-query-token phases: single-token decode against
    # a (possibly sequence-sharded) cache contracts cleanly as one einsum,
    # and the block reshape would break the cache's sequence sharding.
    if q.shape[1] > 1 and k.shape[1] > CHUNK_THRESHOLD:
        return attention_chunked(q, k, v, q_pos, k_pos, window=window,
                                 scale=scale, attn_softcap=attn_softcap)
    return attention_ref(q, k, v, q_pos, k_pos, window=window, scale=scale,
                         attn_softcap=attn_softcap)


def mha_attention_paged(q, pool, block_tables, q_pos, *,
                        window: Optional[int], scale: float,
                        attn_softcap: Optional[float] = None):
    """Decode / mixed-window attention against a paged KV pool
    (continuous batching).

    q: (B,Sq,Hq,D) with Sq == 1 for single-token decode and Sq == W > 1
    for a per-slot query window — a chunked-prefill chunk, a speculative
    verify window, or a decode token padded up to the batch width
    (q_pos (B,Sq) absolute positions, -1 marking padding queries whose
    outputs are zeroed and discarded; the window's own K/V must already
    be written to the pool, so the stored positions make intra-window
    causal masking exact); pool: {"pk"/"pv": (P,page,Hkv,D), "ppos":
    (P,page)}, plus "pk_scale"/"pv_scale" (P,page,Hkv) when the pool
    stores int8; block_tables: (B, pages_per_slot) physical page ids
    (-1 = none).

    Dispatch: paged Pallas kernel (single- or multi-query variant;
    gathers pages in-kernel via scalar-prefetched block tables; int8
    pools dequantize in-register) -> dense gather (dequantizing) +
    reference attention.
    """
    from repro.core import kv_cache as KV
    from repro.kernels import ops as kops
    dispatch = (kops.maybe_paged_decode_attention if q.shape[1] == 1
                else kops.maybe_paged_mixed_attention)
    out = dispatch(
        q, pool["pk"], pool["pv"], pool["ppos"], block_tables, q_pos,
        window=window, scale=scale, attn_softcap=attn_softcap,
        k_scale=pool.get("pk_scale"), v_scale=pool.get("pv_scale"))
    if out is not None:
        return out
    kk, vv, kp = KV.paged_gather(pool, block_tables)
    return mha_attention(q, kk.astype(q.dtype), vv.astype(q.dtype),
                         q_pos, kp, window=window, scale=scale,
                         attn_softcap=attn_softcap)


def mha_attention_paged_packed(q, pool, block_tables, q_pos, slot_ids,
                               meta, *, window: Optional[int], scale: float,
                               attn_softcap: Optional[float] = None):
    """Token-packed ragged attention against a paged KV pool: one flat
    (1, T) query stream covering every slot's decode token and
    prefill-chunk tokens for a whole scheduler iteration.

    q: (1, T, Hq, D); q_pos: (1, T) absolute positions (-1 = padding
    lane, output zeroed); slot_ids: (T,) owning slot per lane (-1 =
    padding); meta: kernel work table from
    ``decode_attention.packed_meta_table`` (may be None — fallback only);
    block_tables: (slots, pages_per_slot).  The stream's own K/V must
    already be in the pool (``kv_cache.paged_write_packed``).

    Dispatch: packed Pallas kernel -> per-token dense gather + the same
    ``mha_attention`` reference the bucketed per-slot fallback uses.
    The fallback gathers each lane's *slot* context in block-table order,
    so every query reduces over exactly the keys, in exactly the order,
    the bucketed path would give it — greedy outputs stay bit-identical
    across the packed and bucketed serving paths.
    """
    from repro.core import kv_cache as KV
    from repro.kernels import ops as kops
    out = kops.maybe_paged_packed_attention(
        q, pool["pk"], pool["pv"], pool["ppos"], block_tables, q_pos,
        meta, window=window, scale=scale, attn_softcap=attn_softcap,
        k_scale=pool.get("pk_scale"), v_scale=pool.get("pv_scale"))
    if out is not None:
        return out
    kk, vv, kp = KV.paged_gather(pool, block_tables)   # (slots, ctx, H, D)
    B = block_tables.shape[0]
    _, T, Hq, _ = q.shape
    safe = jnp.clip(slot_ids, 0, B - 1)
    kp_t = jnp.where((slot_ids >= 0)[:, None], kp[safe], -1)
    out = mha_attention(q.reshape(T, 1, Hq, q.shape[-1]),
                        kk[safe].astype(q.dtype), vv[safe].astype(q.dtype),
                        q_pos.reshape(T, 1), kp_t, window=window,
                        scale=scale, attn_softcap=attn_softcap)
    return out.reshape(1, T, Hq, out.shape[-1])


def position_mask(q_pos, k_pos, window: Optional[int]):
    """(B,Sq,Sk) bool: causal, windowed, and k_pos>=0 validity."""
    m = (k_pos[:, None, :] <= q_pos[:, :, None]) & (k_pos[:, None, :] >= 0)
    if window is not None:
        m &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    return m


def _score_inputs(q, k):
    """attn_bf16 (§Perf): feed half-precision operands straight into the
    MXU with fp32 accumulation instead of materializing fp32 casts of the
    (potentially multi-GB) KV cache."""
    from repro import perf_flags
    if perf_flags.flag("attn_bf16"):
        return q, k
    return q.astype(jnp.float32), k.astype(jnp.float32)


def _pv_inputs(p, v):
    from repro import perf_flags
    if perf_flags.flag("attn_bf16"):
        return p.astype(v.dtype), v
    return p, v.astype(jnp.float32)


def attention_ref(q, k, v, q_pos, k_pos, *, window, scale, attn_softcap=None):
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg, kk = _score_inputs(q.reshape(B, Sq, Hkv, g, D), k)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kk,
                        preferred_element_type=jnp.float32) * scale
    logits = softcap(logits, attn_softcap)
    mask = position_mask(q_pos, k_pos, window)                    # (B,Sq,Sk)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # rows with no valid key (all -inf) -> softmax gives uniform; zero them.
    any_valid = mask.any(-1)[:, None, None, :, None]
    p = jnp.where(any_valid, p, 0.0)
    pp, vv = _pv_inputs(p, v)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pp, vv,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def attention_chunked(q, k, v, q_pos, k_pos, *, window, scale,
                      attn_softcap=None, block: int = KV_BLOCK):
    """Flash-attention algorithm expressed as a lax.scan over KV blocks.

    Never materializes the (Sq, Sk) score matrix: peak extra memory is one
    (B, Hq, Sq, block) tile reused across scan steps.  This is the compiled
    fallback for huge-context graphs on hosts where the Pallas kernel is
    unavailable; math matches attention_ref exactly (tested).
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    nblk = -(-Sk // block)
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    Dv = v.shape[-1]
    kb = k.reshape(B, nblk, block, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, Hkv, Dv).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(B, nblk, block).transpose(1, 0, 2)
    qg = q.reshape(B, Sq, Hkv, g, D)
    acc0 = jnp.zeros((B, Hkv, g, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, Hkv, g, Sq), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)

    def step(carry, blk):
        acc, m_run, den = carry
        kc, vc, pc = blk
        qq, kk = _score_inputs(qg, kc)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qq, kk,
                            preferred_element_type=jnp.float32) * scale
        logits = softcap(logits, attn_softcap)
        mask = position_mask(q_pos, pc, window)                   # (B,Sq,blk)
        logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
        blk_max = jnp.max(logits, axis=-1)
        new_m = jnp.maximum(m_run, blk_max)
        safe_m = jnp.where(jnp.isfinite(new_m), new_m, 0.0)
        alpha = jnp.where(jnp.isfinite(m_run), jnp.exp(m_run - safe_m), 0.0)
        pexp = jnp.exp(logits - safe_m[..., None])
        pexp = jnp.where(mask[:, None, None], pexp, 0.0)
        pp, vv = _pv_inputs(pexp, vc)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", pp, vv,
            preferred_element_type=jnp.float32)
        den = den * alpha + pexp.sum(-1)
        return (acc, new_m, den), None

    (acc, _, den), _ = jax.lax.scan(step, (acc0, m0, d0), (kb, vb, pb))
    out = acc / jnp.maximum(den[..., None], 1e-37)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, Dv)
    return out.astype(q.dtype)


def attn_init(rng, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd),
        "wo": dense_init(ks[3], cfg.num_heads * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = {"w": jnp.zeros((hd,))}
        p["k_norm"] = {"w": jnp.zeros((hd,))}
    return p


def attn_qkv(cfg: ModelConfig, p, x, positions, theta: Optional[float] = None):
    """Project to rotated q, k, v.  x: (B,S,d) -> q(B,S,Hq,D), k/v(B,S,Hkv,D)."""
    B, S, _ = x.shape
    theta = theta if theta is not None else cfg.rope_theta
    hd = cfg.resolved_head_dim
    q = dense_matmul(x, p["wq"]).reshape(B, S, cfg.num_heads, hd)
    k = dense_matmul(x, p["wk"]).reshape(B, S, cfg.num_kv_heads, hd)
    v = dense_matmul(x, p["wv"]).reshape(B, S, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"]["w"])
        k = rmsnorm(k, p["k_norm"]["w"])
    if cfg.pos_emb == "rope":
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    return q, k, v


def attn_out(cfg: ModelConfig, p, ctx):
    B, S = ctx.shape[:2]
    return dense_matmul(ctx.reshape(B, S, -1), p["wo"])


def attn_scale(cfg: ModelConfig) -> float:
    return (cfg.attn_scale if cfg.attn_scale is not None
            else cfg.resolved_head_dim ** -0.5)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn_init(rng, cfg: ModelConfig, width: Optional[int] = None):
    d = cfg.d_model
    w = width or cfg.d_ff
    ks = jax.random.split(rng, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {"wi": dense_init(ks[0], d, w), "wg": dense_init(ks[1], d, w),
                "wo": dense_init(ks[2], w, d)}
    return {"wi": dense_init(ks[0], d, w), "wo": dense_init(ks[2], w, d)}


def ffn_apply(cfg: ModelConfig, p, x):
    h = dense_matmul(x, p["wi"])
    if cfg.activation == "swiglu":
        h = jax.nn.silu(dense_matmul(x, p["wg"])) * h
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(dense_matmul(x, p["wg"]), approximate=True) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    return dense_matmul(h, p["wo"])


# ---------------------------------------------------------------------------
# Embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(cfg: ModelConfig, params, tokens):
    """tokens: (B,S) int or (B,S,C) int for multi-codebook audio."""
    emb = params["embed"]["tokens"]
    if cfg.num_codebooks:
        # emb: (C, V, d), tokens: (B, S, C) — gather per codebook, sum streams
        parts = [jnp.take(emb[c], tokens[..., c], axis=0)
                 for c in range(cfg.num_codebooks)]
        x = sum(parts)
    else:
        x = jnp.take(emb, tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(cfg: ModelConfig, params, x):
    """x: (B,S,d) -> logits fp32. Multi-codebook: (B,S,C,V)."""
    xf = x.astype(jnp.float32)
    if cfg.num_codebooks:
        heads = params["embed"].get("heads")
        if heads is None:
            heads = params["embed"]["tokens"]       # tied: (C,V,d)
        logits = jnp.einsum("bsd,cvd->bscv", xf, heads.astype(jnp.float32))
    else:
        embed = params["embed"]
        if cfg.tie_embeddings:
            # tied models unembed through the int8 copy of the (d, V)
            # transposed gather table when the policy quantized one
            # (precision.compress_weights); the gather table itself is
            # never quantized, so embedding lookups stay exact
            head_q8 = embed.get("head_q8")
            if head_q8 is not None:
                logits = dense_matmul(xf, head_q8)
            else:
                logits = xf @ embed["tokens"].astype(jnp.float32).T
        else:
            logits = dense_matmul(xf, embed["head"])
    return softcap(logits, cfg.final_softcap)


def embed_params_init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 3)
    p = {}
    if cfg.num_codebooks:
        p["tokens"] = jnp.stack([
            embed_init(k, cfg.vocab_size, cfg.d_model)
            for k in jax.random.split(ks[0], cfg.num_codebooks)])
    else:
        p["tokens"] = embed_init(ks[0], cfg.vocab_size, cfg.d_model)
        if not cfg.tie_embeddings:
            p["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size)
    if cfg.pos_emb == "learned":
        p["pos"] = embed_init(ks[2], cfg.max_seq_len, cfg.d_model)
    return p
