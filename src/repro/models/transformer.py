"""Unified decoder over stacks of repeating layer patterns.

Every assigned architecture — dense, MoE(+MLA), xLSTM, Hymba hybrid, VLM and
audio backbones — is this one module driven by its :class:`ModelConfig`.
Layer stacks run as ``lax.scan`` over pattern repeats (HLO stays the size of
one pattern), with caches carried as scan xs/ys for prefill/decode.

Entry points:
    init_params(rng, cfg, policy)
    forward_train(params, cfg, tokens, ...)   -> (logits, aux)
    forward_prefill(params, cfg, tokens, ...) -> (logits, cache)
    forward_decode(params, cfg, tokens, ...)  -> (logits, cache)
    init_cache(cfg, batch, max_len, dtype)    -> cache pytree
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, DENSE_FFN, HYBRID, MLA, MLSTM, MOE_FFN,
                                NO_FFN, SLSTM, LayerSpec, ModelConfig, Stack)
from repro.core import kv_cache as KV
from repro.core.precision import FP32, Policy
from repro.models import attention_mla as MLAT
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def layer_init(rng, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(rng, 6)
    p = {"norm1": L.norm_init(cfg)}
    if spec.mixer == ATTN:
        p["attn"] = L.attn_init(ks[0], cfg)
    elif spec.mixer == MLA:
        p["attn"] = MLAT.mla_init(ks[0], cfg)
    elif spec.mixer == MLSTM:
        p["mixer"] = SSM.mlstm_init(ks[0], cfg)
    elif spec.mixer == SLSTM:
        p["mixer"] = SSM.slstm_init(ks[0], cfg)
    elif spec.mixer == HYBRID:
        p["attn"] = L.attn_init(ks[0], cfg)
        p["mamba"] = SSM.mamba_init(ks[1], cfg)
        p["bn_attn"] = L.norm_init(cfg)
        p["bn_ssm"] = L.norm_init(cfg)
    if cfg.sandwich_norm:
        p["norm1_post"] = L.norm_init(cfg)
    if spec.ffn != NO_FFN:
        p["norm2"] = L.norm_init(cfg)
        p["ffn"] = (L.ffn_init(ks[2], cfg) if spec.ffn == DENSE_FFN
                    else MOE.moe_init(ks[2], cfg))
        if cfg.sandwich_norm:
            p["norm2_post"] = L.norm_init(cfg)
    return p


def _stack_init(rng, cfg: ModelConfig, stack: Stack):
    out = []
    for pi, spec in enumerate(stack.pattern):
        keys = jax.random.split(jax.random.fold_in(rng, pi), stack.repeats)
        out.append(jax.vmap(lambda k, s=spec: layer_init(k, cfg, s))(keys))
    return tuple(out)


def mtp_init(rng, cfg: ModelConfig):
    ks = jax.random.split(rng, 3)
    return {"norm_h": L.norm_init(cfg), "norm_e": L.norm_init(cfg),
            "proj": L.dense_init(ks[0], 2 * cfg.d_model, cfg.d_model),
            "layer": layer_init(ks[1], cfg, LayerSpec(mixer=ATTN,
                                                      ffn=DENSE_FFN))}


def init_params(rng, cfg: ModelConfig, policy: Policy = FP32):
    ks = jax.random.split(rng, len(cfg.stacks) + 3)
    params = {
        "embed": L.embed_params_init(ks[0], cfg),
        "final_norm": L.norm_init(cfg),
        "stacks": tuple(_stack_init(ks[2 + i], cfg, s)
                        for i, s in enumerate(cfg.stacks)),
    }
    if cfg.mtp:
        params["mtp"] = mtp_init(ks[1], cfg)
    return policy.cast_params(params)


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Allocate the full model cache (stacked over scan repeats)."""
    layers = []
    for stack in cfg.stacks:
        per_pos = []
        for spec in stack.pattern:
            one = KV.layer_cache_shape(cfg, spec, batch, max_len, dtype)
            per_pos.append(jax.tree.map(
                lambda a, r=stack.repeats: jnp.tile(
                    a[None], (r,) + (1,) * a.ndim), one))
        layers.append(tuple(per_pos))
    return {"layers": tuple(layers)}


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    """ShapeDtypeStruct version (no allocation) for dry-run lowering."""
    return jax.eval_shape(
        functools.partial(init_cache, cfg, batch, max_len, dtype))


def init_paged_cache(cfg: ModelConfig, *, num_pages: int, page_size: int,
                     max_slots: int, max_len: int, dtype=jnp.bfloat16,
                     kv_dtype: str = "auto"):
    """Paged-pool model cache for continuous batching: attention layers
    share ``num_pages`` fixed-size pages (+1 reserved dump page) indexed
    through per-slot block tables; MLA / recurrent layers keep dense
    per-slot state.  Same stacked-over-repeats layout as init_cache.
    ``kv_dtype`` selects pool storage (int8 adds per-entry scale pools;
    see ``kv_cache.paged_layer_cache_shape``)."""
    layers = []
    for stack in cfg.stacks:
        per_pos = []
        for spec in stack.pattern:
            one = KV.paged_layer_cache_shape(cfg, spec, num_pages, page_size,
                                             max_slots, max_len, dtype,
                                             kv_dtype=kv_dtype)
            per_pos.append(jax.tree.map(
                lambda a, r=stack.repeats: jnp.tile(
                    a[None], (r,) + (1,) * a.ndim), one))
        layers.append(tuple(per_pos))
    return {"layers": tuple(layers)}


# ---------------------------------------------------------------------------
# One layer
# ---------------------------------------------------------------------------


def layer_apply(cfg: ModelConfig, spec: LayerSpec, p, x, *, positions,
                cache_pos, cache, mode: str, max_len: int,
                attend_cache: bool = False, paged=None):
    """Returns (x, new_cache, aux). cache is None in train mode.
    attend_cache: prefill continues from a pre-filled cache (prefix
    caching) — queries attend to cache contents, not just in-context k/v.
    paged: {"block_tables": (B, pages), "active": (B,) bool | None} when
    the cache uses the paged pool layout (continuous batching).
    """
    aux = jnp.zeros((), jnp.float32)
    B, S, _ = x.shape
    window = KV.effective_window(cfg, spec, max_len)
    h = L.apply_norm(cfg, p["norm1"], x)
    new_cache = None
    is_paged = cache is not None and "pk" in cache
    if mode in ("verify", "packed") and (not is_paged
                                         or spec.mixer != ATTN):
        # multi-token windows (speculative verify, chunked prefill, the
        # token-packed ragged stream) are defined only over paged
        # pure-attention layers (the same families prefix sharing
        # supports): ring layers cannot roll back overwrites,
        # recurrent/MLA state has no per-position rewind and no legal
        # mid-prompt chunk boundary.  The engine gates before dispatch;
        # this is the backstop.
        raise NotImplementedError(
            f"{mode} mode is unsupported for layer family '{spec.mixer}' "
            f"/ dense caches")

    # ----- mixer ----------------------------------------------------------
    if spec.mixer in (ATTN, HYBRID):
        theta = (cfg.rope_theta_local
                 if (window is not None and cfg.rope_theta_local is not None)
                 else cfg.rope_theta)
        q, k, v = L.attn_qkv(cfg, p["attn"], h, positions, theta=theta)
        scale = L.attn_scale(cfg)
        if is_paged:
            bt = paged["block_tables"]
            pool = {n: cache[n] for n in KV.PAGED_KEYS if n in cache}
            quant = "pk_scale" in pool
            ring = KV.paged_ring_len(window, pool["ppos"].shape[1],
                                     bt.shape[1])
            if mode == "decode":
                c_attn = KV.paged_write_decode(
                    pool, {"k": k, "v": v}, positions[:, 0], bt,
                    paged.get("active"), ring_len=ring)
                ctx = L.mha_attention_paged(
                    q, c_attn, bt, positions, window=window, scale=scale,
                    attn_softcap=cfg.attn_softcap)
            elif mode == "verify":
                # speculative window: write the pending + drafted tokens'
                # K/V (positions[:, 0] .. positions[:, 0] + K), THEN
                # attend — the stored positions give each of the K+1
                # queries an exact causal mask over earlier drafts.
                # Rejected entries are rewound by the engine afterwards
                # (kv_cache.paged_truncate).
                c_attn = KV.paged_write_decode_multi(
                    pool, {"k": k, "v": v}, positions[:, 0], bt,
                    paged.get("active"), ring_len=ring)
                ctx = L.mha_attention_paged(
                    q, c_attn, bt, positions, window=window, scale=scale,
                    attn_softcap=cfg.attn_softcap)
            elif mode == "packed":
                # token-packed ragged stream: scatter every lane's K/V
                # into its OWN slot's pages, then attend each lane to its
                # slot's whole paged history (block tables are indexed
                # per lane via slot_ids, not per row).
                c_attn = KV.paged_write_packed(
                    pool, {"k": k, "v": v}, paged["slot_ids"],
                    positions[0], bt, ring_len=ring)
                ctx = L.mha_attention_paged_packed(
                    q, c_attn, bt, positions, paged["slot_ids"],
                    paged.get("packed_meta"), window=window, scale=scale,
                    attn_softcap=cfg.attn_softcap)
            elif attend_cache or (quant and window is None):
                # prefix-cached admission: the prompt's suffix is written
                # into this request's own pages first, then queries attend
                # the *gathered* block table — shared prefix pages (mapped
                # zero-copy by the radix cache) and the fresh suffix alike.
                # Only windowless full attention reaches here (ring layers
                # opt out of sharing: their pages are overwritten in
                # place, see prefix_cache.shareable).
                # Quantized pools take this path even without a prefix
                # match (start == 0): attending the written-then-gathered
                # pages means every query sees the same dequantized K/V
                # that decode will later read, which keeps shared-prefix
                # int8 serving bit-identical to unshared int8 serving.
                c_attn = KV.paged_write_prefill(
                    pool, {"k": k, "v": v}, cache_pos, bt, ring_len=ring)
                kk, vv, kp = KV.paged_gather(c_attn, bt)
                ctx = L.mha_attention(q, kk.astype(x.dtype),
                                      vv.astype(x.dtype), positions, kp,
                                      window=window, scale=scale,
                                      attn_softcap=cfg.attn_softcap)
            else:                                   # admission prefill
                ctx = L.mha_attention(q, k, v, positions, positions,
                                      window=window, scale=scale,
                                      attn_softcap=cfg.attn_softcap)
                c_attn = KV.paged_write_prefill(
                    pool, {"k": k, "v": v}, cache_pos, bt, ring_len=ring)
        elif mode == "decode":
            c_attn = {n: cache[n] for n in ("k", "v", "pos")}
            c_attn = KV.write_decode(c_attn, {"k": k, "v": v}, positions[:, 0])
            ctx = L.mha_attention(q, c_attn["k"].astype(x.dtype),
                                  c_attn["v"].astype(x.dtype),
                                  positions, c_attn["pos"], window=window,
                                  scale=scale, attn_softcap=cfg.attn_softcap)
        elif mode == "prefill" and attend_cache:
            c_attn = KV.write_prefill(
                {n: cache[n] for n in ("k", "v", "pos")},
                {"k": k, "v": v}, cache_pos)
            ctx = L.mha_attention(q, c_attn["k"].astype(x.dtype),
                                  c_attn["v"].astype(x.dtype),
                                  positions, c_attn["pos"], window=window,
                                  scale=scale, attn_softcap=cfg.attn_softcap)
        else:
            ctx = L.mha_attention(q, k, v, positions, positions,
                                  window=window, scale=scale,
                                  attn_softcap=cfg.attn_softcap)
            c_attn = None
            if mode == "prefill":
                c_attn = KV.write_prefill(
                    {n: cache[n] for n in ("k", "v", "pos")},
                    {"k": k, "v": v}, cache_pos)
        mixer_out = L.attn_out(cfg, p["attn"], ctx)

        if spec.mixer == HYBRID:
            if mode == "train":
                ssm_state, conv_state = SSM.mamba_zero_state(cfg, B, x.dtype)
            else:
                ssm_state, conv_state = cache["ssm"], cache["conv"]
            ssm_out, ssm_state, conv_state = SSM.mamba_apply(
                cfg, p["mamba"], h, ssm_state, conv_state, mode)
            mixer_out = 0.5 * (L.apply_norm(cfg, p["bn_attn"], mixer_out)
                               + L.apply_norm(cfg, p["bn_ssm"], ssm_out))
            if mode != "train":
                new_cache = dict(c_attn)
                new_cache["ssm"] = ssm_state
                new_cache["conv"] = conv_state
        else:
            new_cache = c_attn

    elif spec.mixer == MLA:
        if mode == "decode":
            mixer_out, new_cache = MLAT.mla_decode(cfg, p["attn"], h, cache,
                                                   positions[:, 0])
        elif mode == "prefill" and attend_cache:
            mixer_out, new_cache = MLAT.mla_prefill_cached(
                cfg, p["attn"], h, cache, positions, cache_pos,
                window=window)
        else:
            mixer_out, to_cache = MLAT.mla_full(cfg, p["attn"], h, positions,
                                                positions, window=window)
            if mode == "prefill":
                new_cache = KV.write_prefill(cache, to_cache, cache_pos)

    elif spec.mixer in (MLSTM, SLSTM):
        fn = SSM.mlstm_apply if spec.mixer == MLSTM else SSM.slstm_apply
        zero = (SSM.mlstm_zero_state if spec.mixer == MLSTM
                else SSM.slstm_zero_state)
        state = zero(cfg, B) if mode == "train" else cache
        mixer_out, state = fn(cfg, p["mixer"], h, state, mode)
        if mode != "train":
            new_cache = state
    else:
        raise ValueError(spec.mixer)

    if cfg.sandwich_norm:
        mixer_out = L.apply_norm(cfg, p["norm1_post"], mixer_out)
    x = x + mixer_out

    # ----- ffn -------------------------------------------------------------
    if spec.ffn != NO_FFN:
        h2 = L.apply_norm(cfg, p["norm2"], x)
        if spec.ffn == DENSE_FFN:
            out = L.ffn_apply(cfg, p["ffn"], h2)
        else:
            kind = "sigmoid" if cfg.mla is not None else "softmax"
            out, moe_aux = MOE.moe_apply(cfg, p["ffn"], h2, kind)
            aux = aux + moe_aux
        if cfg.sandwich_norm:
            out = L.apply_norm(cfg, p["norm2_post"], out)
        x = x + out

    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack runner (scan over repeats)
# ---------------------------------------------------------------------------


def _run_stack(cfg, stack: Stack, stack_p, stack_c, x, *, positions,
               cache_pos, mode, max_len, remat, attend_cache=False,
               paged=None):
    has_cache = mode != "train"

    def body(carry, xs):
        xx, aux = carry
        if has_cache:
            p_r, c_r = xs
        else:
            (p_r,) = xs
            c_r = (None,) * len(stack.pattern)
        new_cs = []
        for pi, spec in enumerate(stack.pattern):
            xx, nc, a = layer_apply(cfg, spec, p_r[pi], xx,
                                    positions=positions, cache_pos=cache_pos,
                                    cache=c_r[pi], mode=mode, max_len=max_len,
                                    attend_cache=attend_cache, paged=paged)
            new_cs.append(nc)
            aux = aux + a
        return (xx, aux), (tuple(new_cs) if has_cache else None)

    if remat and mode == "train":
        body = jax.checkpoint(body)

    xs = (stack_p, stack_c) if has_cache else (stack_p,)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                       xs)
    return x, new_cache, aux


def _run_all(cfg, params, x, *, positions, cache_pos, cache, mode, max_len,
             remat=False, attend_cache=False, paged=None):
    new_layers = []
    aux = jnp.zeros((), jnp.float32)
    for si, stack in enumerate(cfg.stacks):
        sc = cache["layers"][si] if cache is not None else None
        x, nc, a = _run_stack(cfg, stack, params["stacks"][si], sc, x,
                              positions=positions, cache_pos=cache_pos,
                              mode=mode, max_len=max_len, remat=remat,
                              attend_cache=attend_cache, paged=paged)
        new_layers.append(nc)
        aux = aux + a
    new_cache = {"layers": tuple(new_layers)} if cache is not None else None
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Embedding plumbing
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens, prefix_embeds, positions, policy):
    x = L.embed_tokens(cfg, params, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if cfg.pos_emb == "learned":
        pe = params["embed"]["pos"]
        x = x + jnp.take(pe, jnp.clip(positions, 0, pe.shape[0] - 1),
                         axis=0).astype(x.dtype)
    elif cfg.pos_emb == "sinusoidal":
        d = cfg.d_model
        half = d // 2
        freqs = jnp.exp(-jnp.arange(half) / half * jnp.log(10000.0))
        ang = positions[..., None].astype(jnp.float32) * freqs
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pe.astype(x.dtype)
    x = x.astype(policy.compute_dtype)
    return _maybe_seq_parallel(x)


def _maybe_seq_parallel(x):
    """seq_parallel (§Perf): shard the token/sequence dim of activations
    over the `model` axis instead of tensor-parallel weights — the right
    scheme when head counts don't divide the TP degree (GSPMD would
    otherwise reshard full activations around every per-head op).  The
    constraint propagates through the whole stack; attention gathers K/V
    across the axis as needed."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import perf_flags
    from repro.sharding import partition as SH
    if not perf_flags.flag("seq_parallel"):
        return x
    mesh = SH.current_mesh()
    if (mesh is None or "model" not in mesh.axis_names
            or x.shape[1] % mesh.shape["model"] != 0):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(None, "model", None)))


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def forward_train(params, cfg: ModelConfig, tokens, *, prefix_embeds=None,
                  policy: Policy = FP32, remat: bool = True):
    """tokens: (B,S) int32 (or (B,S,C) audio). Returns (logits, aux dict)."""
    B = tokens.shape[0]
    S = tokens.shape[1] + (prefix_embeds.shape[1] if prefix_embeds is not None
                           else 0)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    x = _embed(cfg, params, tokens, prefix_embeds, positions, policy)
    x, _, aux = _run_all(cfg, params, x, positions=positions, cache_pos=None,
                         cache=None, mode="train", max_len=S, remat=remat)
    h_final = L.apply_norm(cfg, params["final_norm"], x)
    logits = policy.output_cast(L.unembed(cfg, params, h_final))
    aux_d = {"moe_aux": aux}
    if cfg.mtp and "mtp" in params:
        aux_d["mtp_logits"] = _mtp_forward(params, cfg, x, tokens, positions,
                                           policy)
    return logits, aux_d


def _mtp_forward(params, cfg, h, tokens, positions, policy):
    """DeepSeek multi-token prediction: predict t_{i+2} from h_i + emb_{i+1}."""
    p = params["mtp"]
    emb_next = L.embed_tokens(cfg, params, tokens[:, 1:]).astype(h.dtype)
    h_cur = h[:, :-1]
    merged = jnp.concatenate(
        [L.apply_norm(cfg, p["norm_h"], h_cur),
         L.apply_norm(cfg, p["norm_e"], emb_next)], axis=-1)
    x = merged @ p["proj"].astype(h.dtype)
    x, _, _ = layer_apply(cfg, LayerSpec(ATTN, DENSE_FFN), p["layer"], x,
                          positions=positions[:, :-1], cache_pos=None,
                          cache=None, mode="train",
                          max_len=positions.shape[1])
    h_final = L.apply_norm(cfg, params["final_norm"], x)
    return policy.output_cast(L.unembed(cfg, params, h_final))


def forward_prefill(params, cfg: ModelConfig, tokens, prompt_lengths, cache,
                    *, prefix_embeds=None, policy: Policy = FP32,
                    max_len: Optional[int] = None, last_only: bool = False,
                    start: int = 0, paged=None):
    """Process full (right-padded) prompts, fill the cache.

    prompt_lengths: (B,) valid token count per row *including* prefix
    embeddings but *excluding* ``start``.  ``start`` > 0 continues from a
    pre-filled cache (prefix caching: the paper's "extract content
    offline" applied to a shared prompt's KV); it may be a static int or
    a per-row (B,) array (paged admission, where each request resumes
    from its own matched prefix length).  Returns
    (logits (B,S,V), cache) — or (B,1,V) when ``last_only`` (production
    serving: unembed only the sampled position, which for a 262k vocab
    saves terabytes of logits at 32k prefill).
    """
    B = tokens.shape[0]
    S = tokens.shape[1] + (prefix_embeds.shape[1] if prefix_embeds is not None
                           else 0)
    max_len = max_len or _cache_max_len(cfg, cache)
    attend = not (isinstance(start, int) and start == 0)
    start = jnp.asarray(start, jnp.int32).reshape(-1, 1)    # (1,1) or (B,1)
    positions = start + jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cache_pos = jnp.where(positions < start + prompt_lengths[:, None],
                          positions, -1)
    x = _embed(cfg, params, tokens, prefix_embeds, positions, policy)
    x, cache, _ = _run_all(cfg, params, x, positions=positions,
                           cache_pos=cache_pos, cache=cache, mode="prefill",
                           max_len=max_len, attend_cache=attend,
                           paged=paged)
    if last_only:
        x = jnp.take_along_axis(
            x, (prompt_lengths - 1)[:, None, None].astype(jnp.int32), axis=1)
    h_final = L.apply_norm(cfg, params["final_norm"], x)
    logits = policy.output_cast(L.unembed(cfg, params, h_final))
    return logits, cache


def forward_decode(params, cfg: ModelConfig, tokens, cache, lengths, *,
                   policy: Policy = FP32, max_len: Optional[int] = None,
                   paged=None):
    """One new token per slot. tokens: (B,1); lengths: (B,) current context
    length (the new token's absolute position). Returns (logits, cache)."""
    B = tokens.shape[0]
    max_len = max_len or _cache_max_len(cfg, cache)
    positions = lengths[:, None]
    x = _embed(cfg, params, tokens, None, positions, policy)
    x, cache, _ = _run_all(cfg, params, x, positions=positions,
                           cache_pos=None, cache=cache, mode="decode",
                           max_len=max_len, paged=paged)
    h_final = L.apply_norm(cfg, params["final_norm"], x)
    logits = policy.output_cast(L.unembed(cfg, params, h_final))
    return logits, cache


def forward_verify(params, cfg: ModelConfig, tokens, cache, lengths, *,
                   policy: Policy = FP32, max_len: Optional[int] = None,
                   paged=None):
    """Speculative verify: score a K+1-token window per slot in ONE
    forward against the paged cache.

    tokens: (B, K+1) — the pending token followed by K drafted tokens;
    lengths: (B,) the pending token's absolute position (same convention
    as :func:`forward_decode`, which is the K == 0 case).  Every layer
    writes the whole window's K/V into its paged pool (masked by
    ``paged["active"]``), and each query position attends causally via
    the stored positions — including the window's own earlier tokens.
    Returns (logits (B, K+1, V), cache); logits[:, j] is the target
    distribution for the token following tokens[:, j], so the rejection
    sampler (``sampling.speculative_verify``) reads acceptance straight
    off this one pass.  The caller must rewind rejected entries
    (``kv_cache.paged_truncate_all``) before the next step retires or
    shares those pages.

    Only paged pure-attention models support verify (see layer_apply's
    gate) — the engine falls back to plain decode elsewhere.
    """
    B, K1 = tokens.shape
    max_len = max_len or _cache_max_len(cfg, cache)
    positions = lengths[:, None] + jnp.arange(K1)[None, :]
    x = _embed(cfg, params, tokens, None, positions, policy)
    x, cache, _ = _run_all(cfg, params, x, positions=positions,
                           cache_pos=None, cache=cache, mode="verify",
                           max_len=max_len, paged=paged)
    h_final = L.apply_norm(cfg, params["final_norm"], x)
    logits = policy.output_cast(L.unembed(cfg, params, h_final))
    return logits, cache


def forward_mixed(params, cfg: ModelConfig, tokens, cache, row_start, n_q, *,
                  policy: Policy = FP32, max_len: Optional[int] = None,
                  paged=None):
    """Mixed chunked-prefill / decode forward: per-slot variable-length
    token windows against the paged pool in one pass.  The unified
    engine calls it with packed single-chunk rows (B = 1, W = the
    iteration's width bucket); the layout is general — any mix of
    decode rows (1 token), chunk rows, and idle rows batches fine.

    tokens: (B, W) — row b carries ``n_q[b]`` real tokens left-aligned
    (1 pending token for decode rows, a prompt chunk for prefill rows,
    0 for idle slots); row_start: (B,) the absolute position of each
    row's first token (its write position).  Every real token's K/V is
    scattered into the slot's pages (``paged_write_decode_multi``,
    quantizing on int8 pools) and each query attends the slot's whole
    paged history — pages written by *earlier* chunks, prefix-cache
    pages mapped zero-copy at admission, and the window's own earlier
    tokens (stored positions make the intra-window causal mask exact),
    so any chunk boundary is legal, page-aligned or not.

    Returns (logits (B, 1, V) at each row's LAST real token, cache):
    for decode rows that is the next-token distribution, for a prompt's
    final chunk it seeds sampling; other chunk rows' logits are
    computed-and-discarded by the caller.  Padding lanes carry -1
    positions: their writes land on the dump page and their queries are
    fully masked (zero output), so idle slots never perturb the pool.

    Gated like speculative verify to paged pure-attention families (see
    ``layer_apply``); the engine falls back to bucketed whole-prompt
    admission elsewhere.
    """
    B, W = tokens.shape
    max_len = max_len or _cache_max_len(cfg, cache)
    valid = jnp.arange(W)[None, :] < n_q[:, None]
    positions = jnp.where(valid,
                          row_start[:, None] + jnp.arange(W)[None, :], -1)
    paged = dict(paged or {})
    paged["active"] = valid
    x = _embed(cfg, params, tokens, None, positions, policy)
    x, cache, _ = _run_all(cfg, params, x, positions=positions,
                           cache_pos=None, cache=cache, mode="verify",
                           max_len=max_len, paged=paged)
    # unembed only each row's sampled position (last real token) — the
    # same logits economy as forward_prefill(last_only=True)
    idx = jnp.maximum(n_q - 1, 0)
    x = jnp.take_along_axis(x, idx[:, None, None].astype(jnp.int32), axis=1)
    h_final = L.apply_norm(cfg, params["final_norm"], x)
    logits = policy.output_cast(L.unembed(cfg, params, h_final))
    return logits, cache


def forward_packed(params, cfg: ModelConfig, tokens, cache, slot_ids,
                   positions, seg_last, *, policy: Policy = FP32,
                   max_len: Optional[int] = None, paged=None):
    """Token-packed ragged forward: a WHOLE scheduler iteration — every
    live slot's decode token plus every admitting slot's prefill-chunk
    tokens — as ONE (1, T) dispatch against the paged pool.

    tokens: (1, T) flat stream (decode tokens first, then chunk tokens,
    zero-padded to the width bucket); slot_ids: (T,) owning slot per
    lane (-1 = padding); positions: (T,) absolute positions (-1 =
    padding); seg_last: (S,) stream index of each segment's LAST real
    token (one segment per decode slot, then one per chunk; padded
    entries point at lane 0 and are discarded by the caller).

    Generalizes :func:`forward_mixed` from per-slot rows to a flat
    ragged stream: no per-chunk width buckets, no per-row padding —
    the only padded lanes are the tail up to the single global bucket
    T, so padded-FLOP waste is ~zero and the engine issues one dispatch
    per iteration instead of ``1 + #chunks``.  K/V writes are scattered
    per lane into each lane's own slot's pages
    (``kv_cache.paged_write_packed``: quant-aware, dump-page routed for
    padding, COW-safe because admission re-points fresh pages before
    dispatch exactly as on the bucketed path), and each lane attends its
    slot's whole paged history under its own causal mask.

    Returns (logits (1, S, V) at each segment's last token, cache) —
    decode segments read their next-token distribution, final chunks
    seed sampling, earlier chunks are computed-and-discarded.  Gated
    like verify/mixed to paged pure-attention families.
    """
    max_len = max_len or _cache_max_len(cfg, cache)
    pos2 = positions[None, :]
    paged = dict(paged or {})
    paged["slot_ids"] = slot_ids
    x = _embed(cfg, params, tokens, None, pos2, policy)
    x, cache, _ = _run_all(cfg, params, x, positions=pos2, cache_pos=None,
                           cache=cache, mode="packed", max_len=max_len,
                           paged=paged)
    # unembed only the sampled positions (forward_mixed's logits economy,
    # one gather for all segments)
    x = jnp.take_along_axis(x, seg_last[None, :, None].astype(jnp.int32),
                            axis=1)
    h_final = L.apply_norm(cfg, params["final_norm"], x)
    logits = policy.output_cast(L.unembed(cfg, params, h_final))
    return logits, cache


def _cache_max_len(cfg: ModelConfig, cache) -> int:
    """Recover the max_len the cache was built with (largest pos dim - 1)."""
    best = 0
    for stack_c in cache["layers"]:
        for c in stack_c:
            if isinstance(c, dict) and "pos" in c:
                best = max(best, c["pos"].shape[-1] - 1)
    return best or cfg.max_seq_len
