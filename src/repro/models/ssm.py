"""Recurrent mixers: xLSTM (mLSTM + sLSTM) and a Mamba-style selective SSM.

TPU adaptation (see DESIGN.md §3): the GPU reference implementations of
these models use fused CUDA scans.  Here the parallelizable ones (mLSTM,
Mamba branch) run in *chunkwise* form — intra-chunk quadratic matmuls that
map onto the MXU, inter-chunk state carried through a ``lax.scan`` — which
is the TPU-native realization of the same recurrence.  sLSTM has a true
hidden-to-hidden dependency and runs as a time scan.

Each mixer exposes:
    *_apply(cfg, p, x, state, mode)  ->  (y, new_state)
with ``mode`` in {"train", "prefill", "decode"}; states are fp32 and act as
the KV-cache generalization for attention-free layers (paper pillar P1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

CHUNK = 128


def mlstm_zero_state(cfg: ModelConfig, batch: int):
    H, dh = cfg.num_heads, (2 * cfg.d_model) // cfg.num_heads
    return {"C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
            "m": jnp.zeros((batch, H), jnp.float32)}


def slstm_zero_state(cfg: ModelConfig, batch: int):
    H, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
    z = lambda *s: jnp.zeros(s, jnp.float32)
    return {"c": z(batch, H, dh), "n": z(batch, H, dh),
            "h": z(batch, H, dh), "m": z(batch, H)}


def mamba_zero_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return (jnp.zeros((batch, di, s.state_size), jnp.float32),
            jnp.zeros((batch, s.conv_size - 1, di), dtype))


def _pad_to(x, mult, axis):
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


# ===========================================================================
# mLSTM (xLSTM matrix-memory block)
# ===========================================================================


def mlstm_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    di = 2 * d
    H = cfg.num_heads
    ks = jax.random.split(rng, 8)
    return {
        "w_up": L.dense_init(ks[0], d, di),
        "w_gate": L.dense_init(ks[1], d, di),
        "wq": L.dense_init(ks[2], di, di),
        "wk": L.dense_init(ks[3], di, di),
        "wv": L.dense_init(ks[4], di, di),
        "w_if": L.dense_init(ks[5], di, 2 * H),
        "b_if": jnp.concatenate([jnp.zeros((H,)), 3.0 * jnp.ones((H,))]),
        "out_norm": {"w": jnp.zeros((di,))},
        "w_down": L.dense_init(ks[6], di, d),
    }


def _mlstm_qkvgates(cfg, p, x):
    B, S, d = x.shape
    H = cfg.num_heads
    xi = x @ p["w_up"].astype(x.dtype)
    z = x @ p["w_gate"].astype(x.dtype)
    di = xi.shape[-1]
    dh = di // H
    q = (xi @ p["wq"].astype(x.dtype)).reshape(B, S, H, dh)
    k = (xi @ p["wk"].astype(x.dtype)).reshape(B, S, H, dh) * dh ** -0.5
    v = (xi @ p["wv"].astype(x.dtype)).reshape(B, S, H, dh)
    gates = (xi.astype(jnp.float32) @ p["w_if"].astype(jnp.float32)
             + p["b_if"])
    i_pre, f_pre = gates[..., :H], gates[..., H:]                 # (B,S,H)
    logf = jax.nn.log_sigmoid(f_pre)
    return q, k, v, i_pre, logf, z


def mlstm_chunked(q, k, v, i_pre, logf, state):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B,S,H,dh); i_pre/logf: (B,S,H) fp32.
    state: {"C": (B,H,dh,dh), "n": (B,H,dh), "m": (B,H)} fp32.
    Returns h (B,S,H,dh) fp32 and the final state.
    """
    B, S, H, dh = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    (qf, pad), (kf, _), (vf, _) = (_pad_to(t, CHUNK, 1) for t in (qf, kf, vf))
    i_pre, _ = _pad_to(i_pre, CHUNK, 1)
    logf, _ = _pad_to(logf, CHUNK, 1)
    # padded steps: make them no-ops (f=1 -> logf=0, i=-inf)
    if pad:
        Sp = qf.shape[1]
        step_ok = jnp.arange(Sp) < S
        logf = jnp.where(step_ok[None, :, None], logf, 0.0)
        i_pre = jnp.where(step_ok[None, :, None], i_pre, -1e30)
    nchunk = qf.shape[1] // CHUNK

    def to_chunks(t):
        return t.reshape(B, nchunk, CHUNK, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = map(to_chunks, (qf, kf, vf, i_pre, logf))

    tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))                # j <= i

    def step(carry, blk):
        C_p, n_p, m_p = carry                                     # prev state
        qb, kb, vb, ib, fb = blk                                  # (B,L,H,...)
        F = jnp.cumsum(fb, axis=1)                                # (B,L,H)
        Ftot = F[:, -1]                                           # (B,H)
        # intra-chunk log weights: F_i - F_j + i_j   (B,H,L,L)
        logw = (F.transpose(0, 2, 1)[:, :, :, None]
                - F.transpose(0, 2, 1)[:, :, None, :]
                + ib.transpose(0, 2, 1)[:, :, None, :])
        logw = jnp.where(tri, logw, -jnp.inf)
        # state path log decay per position: F_i + m_prev
        logst = F.transpose(0, 2, 1) + m_p[:, :, None]            # (B,H,L)
        m_i = jnp.maximum(jnp.max(logw, axis=-1), logst)          # (B,H,L)
        m_i = jnp.where(jnp.isfinite(m_i), m_i, 0.0)
        w = jnp.exp(logw - m_i[..., None])                        # (B,H,L,L)
        st_w = jnp.exp(logst - m_i)                               # (B,H,L)

        scores = jnp.einsum("blhd,bmhd->bhlm", qb, kb) * w
        num = (jnp.einsum("bhlm,bmhd->bhld", scores, vb)
               + st_w[..., None] * jnp.einsum("blhd,bhde->bhle", qb, C_p))
        den = scores.sum(-1) + st_w * jnp.einsum("blhd,bhd->bhl", qb, n_p)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        h = h.transpose(0, 2, 1, 3)                               # (B,L,H,dh)

        # ---- state update to chunk end --------------------------------
        m_new = jnp.maximum(m_p + Ftot,
                            jnp.max(Ftot[:, None] - F + ib, axis=1))
        decay_state = jnp.exp(m_p + Ftot - m_new)                 # (B,H)
        wk_end = jnp.exp(Ftot[:, None] - F + ib - m_new[:, None]) # (B,L,H)
        C_n = (decay_state[..., None, None] * C_p
               + jnp.einsum("blh,blhd,blhe->bhde", wk_end, kb, vb))
        n_n = (decay_state[..., None] * n_p
               + jnp.einsum("blh,blhd->bhd", wk_end, kb))
        return (C_n, n_n, m_new), h

    carry0 = (state["C"], state["n"], state["m"])
    (C_f, n_f, m_f), hs = jax.lax.scan(step, carry0, (qc, kc, vc, ic, fc))
    h = hs.swapaxes(0, 1).reshape(B, nchunk * CHUNK, H, dh)[:, :S]
    return h, {"C": C_f, "n": n_f, "m": m_f}


def mlstm_step(q, k, v, i_pre, logf, state):
    """Single-token recurrent update. q,k,v: (B,1,H,dh)."""
    qf, kf, vf = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    ib, fb = i_pre[:, 0], logf[:, 0]                              # (B,H)
    C_p, n_p, m_p = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(fb + m_p, ib)
    fw = jnp.exp(fb + m_p - m_new)
    iw = jnp.exp(ib - m_new)
    C_n = fw[..., None, None] * C_p + iw[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", kf, vf)
    n_n = fw[..., None] * n_p + iw[..., None] * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C_n)
    den = jnp.einsum("bhd,bhd->bh", qf, n_n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h[:, None], {"C": C_n, "n": n_n, "m": m_new}


def mlstm_apply(cfg: ModelConfig, p, x, state, mode: str):
    B, S, d = x.shape
    H = cfg.num_heads
    q, k, v, i_pre, logf, z = _mlstm_qkvgates(cfg, p, x)
    if mode == "decode":
        h, new_state = mlstm_step(q, k, v, i_pre, logf, state)
    else:
        from repro.kernels import ops as kops
        out = kops.maybe_mlstm_chunked(q, k, v, i_pre, logf, state)
        if out is not None:
            h, new_state = out
        else:
            h, new_state = mlstm_chunked(q, k, v, i_pre, logf, state)
    di = z.shape[-1]
    h = h.reshape(B, S, di).astype(x.dtype)
    h = L.rmsnorm(h, p["out_norm"]["w"])
    out = (h * jax.nn.silu(z)) @ p["w_down"].astype(x.dtype)
    return out, new_state


# ===========================================================================
# sLSTM (xLSTM scalar-memory block) — true recurrence, time scan
# ===========================================================================


def slstm_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(rng, 4)
    return {
        "w_in": L.dense_init(ks[0], d, 4 * d),                    # i,f,z,o
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh)) * dh ** -0.5),
        "b": jnp.concatenate([jnp.zeros((d,)), 3.0 * jnp.ones((d,)),
                              jnp.zeros((2 * d,))]),
        "out_norm": {"w": jnp.zeros((d,))},
        "w_out": L.dense_init(ks[2], d, d),
    }


def _slstm_cell(cfg, p, wx_t, st):
    """wx_t: (B,4d) input preactivations; st: dict of (B,H,dh)."""
    H = cfg.num_heads
    B = wx_t.shape[0]
    d = wx_t.shape[-1] // 4
    dh = d // H
    rec = jnp.einsum("bhd,hde->bhe", st["h"], p["r"].astype(jnp.float32))
    pre = wx_t.reshape(B, 4, H, dh).transpose(0, 2, 1, 3).reshape(B, H, 4 * dh)
    pre = pre + rec + p["b"].reshape(4, H, dh).transpose(1, 0, 2).reshape(
        H, 4 * dh)
    i_pre, f_pre, z_pre, o_pre = jnp.split(pre, 4, axis=-1)       # (B,H,dh)
    logf = jax.nn.log_sigmoid(f_pre)
    # one stabilizer per head (shared across dims): exact for any choice,
    # numerically safe when >= the per-dim max.
    m_prev = st["m"][:, :, None]                                  # (B,H,1)
    m_new = jnp.maximum(logf + m_prev, i_pre).max(-1)             # (B,H)
    fw = jnp.exp(logf + m_prev - m_new[..., None])
    iw = jnp.exp(i_pre - m_new[..., None])
    c = fw * st["c"] + iw * jnp.tanh(z_pre)
    n = fw * st["n"] + iw
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(cfg: ModelConfig, p, x, state, mode: str):
    B, S, d = x.shape
    H = cfg.num_heads
    dh = d // H
    wx = x.astype(jnp.float32) @ p["w_in"].astype(jnp.float32)    # (B,S,4d)

    if mode == "decode":
        st = _slstm_cell(cfg, p, wx[:, 0], state)
        h_seq = st["h"][:, None]                                  # (B,1,H,dh)
        new_state = st
    else:
        def step(st, wx_t):
            st = _slstm_cell(cfg, p, wx_t, st)
            return st, st["h"]

        new_state, hs = jax.lax.scan(step, state, wx.swapaxes(0, 1))
        h_seq = hs.swapaxes(0, 1)                                 # (B,S,H,dh)

    h = h_seq.reshape(B, -1, d).astype(x.dtype)
    h = L.rmsnorm(h, p["out_norm"]["w"])
    return h @ p["w_out"].astype(x.dtype), new_state


# ===========================================================================
# Mamba-style selective SSM branch (Hymba hybrid heads)
# ===========================================================================
# Scalar-decay-per-head (Mamba-2 form) so the recurrence runs chunkwise on
# the MXU; see DESIGN.md for why this TPU adaptation replaces the Mamba-1
# diagonal-per-channel CUDA scan.


def mamba_init(rng, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    Hs = s.num_ssm_heads or cfg.num_heads
    ks = jax.random.split(rng, 7)
    return {
        "w_in": L.dense_init(ks[0], d, di),
        "w_gate": L.dense_init(ks[1], d, di),
        "conv": jax.random.normal(ks[2], (s.conv_size, di)) * 0.2,
        "w_bc": L.dense_init(ks[3], di, 2 * s.state_size),
        "w_dt": L.dense_init(ks[4], di, Hs),
        "dt_bias": jnp.zeros((Hs,)),
        "a_log": jnp.log(jnp.linspace(1.0, float(Hs), Hs)),
        "skip_d": jnp.ones((Hs,)),
        "out_norm": {"w": jnp.zeros((di,))},
        "w_out": L.dense_init(ks[5], di, d),
    }


def _causal_conv(x, w, conv_state):
    """Depthwise causal conv. x: (B,S,di), w: (K,di), conv_state: (B,K-1,di)."""
    K = w.shape[0]
    xc = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xc[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
              for i in range(K))
    new_state = xc[:, -(K - 1):] if K > 1 else conv_state
    return out, new_state


def mamba_apply(cfg: ModelConfig, p, x, state, conv_state, mode: str):
    """Selective SSM. state: (B, di, N) fp32 -> reshaped (B,Hs,dh,N)."""
    s = cfg.ssm
    B, S, d = x.shape
    di = s.expand * d
    Hs = s.num_ssm_heads or cfg.num_heads
    dh = di // Hs
    N = s.state_size

    xi = x @ p["w_in"].astype(x.dtype)
    z = x @ p["w_gate"].astype(x.dtype)
    xi, new_conv = _causal_conv(xi, p["conv"], conv_state)
    xi = jax.nn.silu(xi)

    bc = xi @ p["w_bc"].astype(x.dtype)
    Bt, Ct = bc[..., :N].astype(jnp.float32), bc[..., N:].astype(jnp.float32)
    dt = jax.nn.softplus(xi.astype(jnp.float32) @ p["w_dt"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                                      # (Hs,) < 0
    logdec = dt * a                                               # (B,S,Hs)
    xh = xi.astype(jnp.float32).reshape(B, S, Hs, dh)
    h_state = state.reshape(B, Hs, dh, N)

    if mode == "decode":
        dec = jnp.exp(logdec[:, 0])                               # (B,Hs)
        upd = jnp.einsum("bhd,bn,bh->bhdn", xh[:, 0], Bt[:, 0], dt[:, 0])
        h_new = dec[..., None, None] * h_state + upd
        y = jnp.einsum("bhdn,bn->bhd", h_new, Ct[:, 0])[:, None]  # (B,1,Hs,dh)
        h_final = h_new
    else:
        y, h_final = _mamba_chunked(xh, Bt, Ct, dt, logdec, h_state)

    y = y + xh[:, :y.shape[1]] * p["skip_d"][:, None]
    y = y.reshape(B, -1, di).astype(x.dtype)
    y = L.rmsnorm(y, p["out_norm"]["w"])
    out = (y * jax.nn.silu(z)) @ p["w_out"].astype(x.dtype)
    return out, h_final.reshape(B, di, N), new_conv


def _mamba_chunked(xh, Bt, Ct, dt, logdec, h0):
    """Chunkwise linear recurrence.  xh: (B,S,H,dh), Bt/Ct: (B,S,N),
    dt/logdec: (B,S,H), h0: (B,H,dh,N)."""
    B, S, H, dh = xh.shape
    N = Bt.shape[-1]
    (xh, pad), (Bt, _), (Ct, _) = (_pad_to(t, CHUNK, 1) for t in (xh, Bt, Ct))
    dt, _ = _pad_to(dt, CHUNK, 1)
    logdec, _ = _pad_to(logdec, CHUNK, 1)
    if pad:
        ok = jnp.arange(xh.shape[1]) < S
        dt = jnp.where(ok[None, :, None], dt, 0.0)
        logdec = jnp.where(ok[None, :, None], logdec, 0.0)
    nchunk = xh.shape[1] // CHUNK

    def to_chunks(t):
        return t.reshape(B, nchunk, CHUNK, *t.shape[2:]).swapaxes(0, 1)

    xc, bc, cc, dc, lc = map(to_chunks, (xh, Bt, Ct, dt, logdec))
    tri = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))

    def step(h_p, blk):
        xb, bb, cb, db, lb = blk
        F = jnp.cumsum(lb, axis=1)                                # (B,L,H)
        Ftot = F[:, -1]
        # intra: w_ij = exp(F_i - F_j) dt_j, j <= i
        logw = (F.transpose(0, 2, 1)[..., :, None]
                - F.transpose(0, 2, 1)[..., None, :])             # (B,H,L,L)
        w = jnp.where(tri, jnp.exp(logw), 0.0)
        scores = jnp.einsum("bln,bmn->blm", cb, bb)[:, None] * w \
            * db.transpose(0, 2, 1)[:, :, None, :]                # (B,H,L,L)
        y_intra = jnp.einsum("bhlm,bmhd->blhd", scores, xb)
        y_state = jnp.einsum("bln,bhdn,blh->blhd", cb, h_p,
                             jnp.exp(F))
        # state to chunk end
        wk = jnp.exp(Ftot[:, None] - F) * db                      # (B,L,H)
        upd = jnp.einsum("blh,blhd,bln->bhdn", wk, xb, bb)
        h_n = jnp.exp(Ftot)[..., None, None] * h_p + upd
        return h_n, y_intra + y_state

    h_f, ys = jax.lax.scan(step, h0, (xc, bc, cc, dc, lc))
    y = ys.swapaxes(0, 1).reshape(B, nchunk * CHUNK, H, dh)[:, :S]
    return y, h_f
