"""Mixture-of-Experts FFN (DeepSeek-V3 / Qwen3-MoE style).

Dispatch is sort-based with static capacity (GShard-style), expressed as an
expert-batched einsum ``ecd,edf->ecf`` whose expert axis shards over the
``model`` mesh axis (expert parallelism).  Token gather/scatter around the
einsum becomes an all-to-all-ish collective pattern under pjit.

Router options: softmax top-k (Qwen3-MoE) or sigmoid scores normalized over
the selected top-k (DeepSeek-V3), plus optional shared experts and the
standard load-balance auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def moe_init(rng, cfg: ModelConfig):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(rng, 6)

    def expert_stack(rng_key, i, o):
        keys = jax.random.split(rng_key, E)
        return jax.vmap(lambda k: L.dense_init(k, i, o))(keys)

    p = {
        "router": L.dense_init(ks[0], d, E),
        "wi": expert_stack(ks[1], d, f),
        "wg": expert_stack(ks[2], d, f),
        "wo": expert_stack(ks[3], f, d),
    }
    if m.num_shared_experts:
        p["shared"] = L.ffn_init(ks[4], cfg, m.num_shared_experts * f)
    return p


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(num_tokens * m.top_k * m.capacity_factor / m.num_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8, >= 8


def moe_apply(cfg: ModelConfig, p, x, router_kind: str = "softmax"):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar).

    Two dispatch backends:
      * capacity (default): GShard-style static capacity + expert-batched
        einsum — the paper-faithful baseline, sheds overflow tokens.
      * ragged (``REPRO_PERF_OPTS=moe_ragged``, beyond-paper): TPU-native
        ``jax.lax.ragged_dot`` grouped matmul over expert-sorted tokens —
        no capacity, no drops, no padded (E, C, d) gather buffer.
    """
    from repro import perf_flags
    if perf_flags.flag("moe_ragged"):
        return moe_apply_ragged(cfg, p, x, router_kind)
    return moe_apply_capacity(cfg, p, x, router_kind)


def _route(cfg: ModelConfig, p, xf, router_kind: str):
    """Shared router: -> (topw (T,k), tope (T,k), aux scalar)."""
    m = cfg.moe
    T = xf.shape[0]
    E, k = m.num_experts, m.top_k
    scores = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if router_kind == "sigmoid":                     # DeepSeek-V3
        probs = jax.nn.sigmoid(scores)
        topw, tope = jax.lax.top_k(probs, k)
        topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)
        lb_probs = probs / (probs.sum(-1, keepdims=True) + 1e-9)
    else:                                            # softmax (Qwen3-MoE)
        probs = jax.nn.softmax(scores, axis=-1)
        topw, tope = jax.lax.top_k(probs, k)
        topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)
        lb_probs = probs
    onehot = jax.nn.one_hot(tope, E, dtype=jnp.float32)           # (T,k,E)
    frac_tokens = onehot.sum((0, 1)) / (T * k)
    aux = m.router_aux_coef * E * jnp.sum(frac_tokens * lb_probs.mean(0))
    return topw, tope, aux


def moe_apply_ragged(cfg: ModelConfig, p, x, router_kind: str = "softmax"):
    """Grouped-matmul dispatch via jax.lax.ragged_dot (beyond-paper)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    xf = x.reshape(T, d)
    topw, tope, aux = _route(cfg, p, xf, router_kind)

    e_flat = tope.reshape(-1)
    t_flat = jnp.repeat(jnp.arange(T), k)
    w_flat = topw.reshape(-1)
    order = jnp.argsort(e_flat)
    st, sw = t_flat[order], w_flat[order]
    sizes = jnp.bincount(e_flat, length=E).astype(jnp.int32)

    rows = xf[st]                                                 # (T*k, d)
    h = jax.lax.ragged_dot(rows, p["wi"].astype(x.dtype), sizes)
    if cfg.activation in ("swiglu", "geglu"):
        g = jax.lax.ragged_dot(rows, p["wg"].astype(x.dtype), sizes)
        act = jax.nn.silu if cfg.activation == "swiglu" else \
            (lambda t: jax.nn.gelu(t, approximate=True))
        h = act(g) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    eo = jax.lax.ragged_dot(h, p["wo"].astype(x.dtype), sizes)    # (T*k, d)
    eo = eo * sw[:, None].astype(eo.dtype)
    out = jnp.zeros((T, d), eo.dtype).at[st].add(eo)
    if "shared" in p:
        out = out + L.ffn_apply(cfg, p["shared"], xf)
    return out.reshape(B, S, d), aux


def moe_apply_capacity(cfg: ModelConfig, p, x,
                       router_kind: str = "softmax"):
    """x: (B, S, d) -> (out (B,S,d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    C = _capacity(T, cfg)
    xf = x.reshape(T, d)

    scores = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if router_kind == "sigmoid":                     # DeepSeek-V3
        probs = jax.nn.sigmoid(scores)
        topw, tope = jax.lax.top_k(probs, k)
        topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)
        lb_probs = probs / (probs.sum(-1, keepdims=True) + 1e-9)
    else:                                            # softmax (Qwen3-MoE)
        probs = jax.nn.softmax(scores, axis=-1)
        topw, tope = jax.lax.top_k(probs, k)
        topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)
        lb_probs = probs

    # ---- load-balance aux loss ------------------------------------------
    onehot = jax.nn.one_hot(tope, E, dtype=jnp.float32)           # (T,k,E)
    frac_tokens = onehot.sum((0, 1)) / (T * k)                    # f_e
    mean_prob = lb_probs.mean(0)                                  # P_e
    aux = m.router_aux_coef * E * jnp.sum(frac_tokens * mean_prob)

    # ---- sort-based dispatch --------------------------------------------
    e_flat = tope.reshape(-1)                                     # (T*k,)
    t_flat = jnp.repeat(jnp.arange(T), k)
    w_flat = topw.reshape(-1)
    order = jnp.argsort(e_flat)
    se, st, sw = e_flat[order], t_flat[order], w_flat[order]
    counts = jnp.bincount(e_flat, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(T * k) - offsets[se]
    keep = pos_in_e < C
    slot = jnp.where(keep, se * C + pos_in_e, E * C)              # overflow slot

    table = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(st.astype(jnp.int32))
    wtab = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(sw)
    table, wtab = table[:-1].reshape(E, C), wtab[:-1].reshape(E, C)

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])     # sentinel row
    gathered = xpad[table]                                        # (E, C, d)

    # ---- expert compute (expert axis shards over `model`) ---------------
    h = jnp.einsum("ecd,edf->ecf", gathered, p["wi"].astype(x.dtype))
    if cfg.activation in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", gathered, p["wg"].astype(x.dtype))
        act = jax.nn.silu if cfg.activation == "swiglu" else \
            (lambda t: jax.nn.gelu(t, approximate=True))
        h = act(g) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    eo = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))   # (E, C, d)

    # ---- combine ----------------------------------------------------------
    eo = eo * wtab[..., None].astype(eo.dtype)
    out = jnp.zeros((T + 1, d), eo.dtype).at[table.reshape(-1)].add(
        eo.reshape(-1, d))[:T]

    if "shared" in p:
        out = out + L.ffn_apply(cfg, p["shared"], xf)
    return out.reshape(B, S, d), aux
