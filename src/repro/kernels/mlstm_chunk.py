"""Chunkwise mLSTM Pallas TPU kernel (xLSTM matrix-memory recurrence).

The TPU-native form of the xLSTM fused CUDA kernel (DESIGN.md §3): the
stabilized matrix-memory recurrence runs chunk-by-chunk with the carry
state (C: dh x dh, n: dh, m: scalar per head) resident in VMEM scratch —
intra-chunk math is (L x L) / (L x dh) MXU matmuls, inter-chunk state
never round-trips HBM.

  grid = (B, H, num_chunks)   (chunks innermost, sequential)

Matches ``repro.models.ssm.mlstm_chunked`` (the jnp oracle) exactly; the
wrapper takes the same (B, S, H, dh) layouts and the same state dict.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def shape_supported(q, chunk: int = DEFAULT_CHUNK) -> bool:
    B, S, H, dh = q.shape
    return S % min(chunk, S) == 0 and dh % 8 == 0 and S >= 1


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref, c0_ref, n0_ref, m0_ref,
            h_ref, cf_ref, nf_ref, mf_ref, c_scr, n_scr, m_scr, *, nchunk):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        c_scr[...] = c0_ref[0, 0]
        n_scr[...] = n0_ref[0, 0]
        m_scr[...] = m0_ref[0]

    q = q_ref[0, 0].astype(jnp.float32)            # (L, dh)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    ib = i_ref[0, 0].astype(jnp.float32)           # (L,)
    fb = f_ref[0, 0].astype(jnp.float32)           # (L,) log forget
    L = q.shape[0]

    C_p = c_scr[...]
    n_p = n_scr[...]
    m_p = m_scr[0]

    F = jnp.cumsum(fb)                              # (L,)
    Ftot = F[-1]
    # intra-chunk log weights: F_i - F_j + i_j  (j <= i)
    logw = F[:, None] - F[None, :] + ib[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    logw = jnp.where(tri, logw, -jnp.inf)
    logst = F + m_p                                 # state path decay (L,)
    m_i = jnp.maximum(jnp.max(logw, axis=-1), logst)
    m_i = jnp.where(jnp.isfinite(m_i), m_i, 0.0)
    w = jnp.exp(logw - m_i[:, None])
    st_w = jnp.exp(logst - m_i)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * w
    num = (jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
           + st_w[:, None] * jax.lax.dot_general(
               q, C_p, (((1,), (0,)), ((), ())),
               preferred_element_type=jnp.float32))
    den = scores.sum(-1) + st_w * (q @ n_p)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[:, None]
    h_ref[0, 0] = h.astype(h_ref.dtype)

    # ---- state update to chunk end -----------------------------------
    m_new = jnp.maximum(m_p + Ftot, jnp.max(Ftot - F + ib))
    decay = jnp.exp(m_p + Ftot - m_new)
    wk_end = jnp.exp(Ftot - F + ib - m_new)         # (L,)
    c_scr[...] = decay * C_p + jax.lax.dot_general(
        k * wk_end[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_scr[...] = decay * n_p + (wk_end[None, :] @ k)[0]
    m_scr[...] = m_new[None]

    @pl.when(ic == nchunk - 1)
    def _finish():
        cf_ref[0, 0] = c_scr[...]
        nf_ref[0, 0] = n_scr[...]
        mf_ref[0, 0] = m_scr[0]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunked_kernel(q, k, v, i_pre, logf, state, *,
                         chunk: int = DEFAULT_CHUNK,
                         interpret: bool = False):
    """q,k,v: (B,S,H,dh); i_pre/logf: (B,S,H); state: {"C","n","m"}.

    Returns (h (B,S,H,dh) fp32, new_state) — same contract as
    ``ssm.mlstm_chunked``.
    """
    B, S, H, dh = q.shape
    L = min(chunk, S)
    nchunk = S // L
    # (B,H,S,dh) layouts for clean chunk blocking
    qt = q.transpose(0, 2, 1, 3)
    kt = (k.transpose(0, 2, 1, 3))
    vt = v.transpose(0, 2, 1, 3)
    it = i_pre.transpose(0, 2, 1)
    ft = logf.transpose(0, 2, 1)

    kernel = functools.partial(_kernel, nchunk=nchunk)
    grid = (B, H, nchunk)
    spec_seq = pl.BlockSpec((1, 1, L, dh), lambda b, h, c: (b, h, c, 0))
    spec_gate = pl.BlockSpec((1, 1, L), lambda b, h, c: (b, h, c))
    spec_state = pl.BlockSpec((1, 1, dh, dh), lambda b, h, c: (b, h, 0, 0))
    spec_vec = pl.BlockSpec((1, 1, dh), lambda b, h, c: (b, h, 0))
    spec_scal = pl.BlockSpec((1, 1), lambda b, h, c: (b, h))

    h_out, c_f, n_f, m_f = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec_seq, spec_seq, spec_seq, spec_gate, spec_gate,
                  spec_state, spec_vec, spec_scal],
        out_specs=[spec_seq, spec_state, spec_vec, spec_scal],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((dh,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt, it, ft, state["C"], state["n"], state["m"])
    return (h_out.transpose(0, 2, 1, 3),
            {"C": c_f, "n": n_f, "m": m_f})
