"""Flash attention Pallas TPU kernel (prefill / train phase).

The paper's FasterTransformer fuses the attention phases to avoid HBM
round-trips of the S^2 score matrix; the TPU-native realization is the
flash algorithm with VMEM-resident running softmax state:

  grid = (B, Hq, num_q_blocks, num_k_blocks)   (k innermost, sequential)

Each step streams one (block_q x D) query tile and one (block_k x D) KV
tile HBM->VMEM, updates the running (max, denom, accumulator) scratch, and
writes the output tile once on the last k step.  GQA is handled with zero
data movement: the k/v BlockSpec index_map folds the q-head index onto its
kv head (h // group).  MXU alignment: D padded to 128 multiples by the
caller contract; block_q/block_k default 128.

Masking is position-driven (absolute q_pos/k_pos arrays, -1 = invalid),
covering causal, sliding-window, ragged right-padding and ring caches with
one code path — identical semantics to ``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.3819763e38
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def shape_supported(q, k, block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K) -> bool:
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if Sq < 2:                       # decode shape -> decode kernel
        return False
    return (Hq % Hkv == 0
            and D % 8 == 0 and k.shape[3] % 8 == 0
            and Sq % min(block_q, Sq) == 0
            and Sk % min(block_k, Sk) == 0)


def _kernel(q_ref, k_ref, v_ref, qp_ref, kp_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, attn_softcap, window, nk):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)              # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)              # (bk, Dv)
    qp = qp_ref[0, :]                                      # (bq,)
    kp = kp_ref[0, :]                                      # (bk,)

    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # (bq, bk)
    if attn_softcap is not None:
        logits = jnp.tanh(logits / attn_softcap) * attn_softcap
    mask = (kp[None, :] <= qp[:, None]) & (kp[None, :] >= 0)
    if window is not None:
        mask &= kp[None, :] > (qp[:, None] - window)
    logits = jnp.where(mask, logits, -jnp.inf)

    m_prev = m_scr[...]
    m_blk = jnp.max(logits, axis=-1)
    m_new = jnp.maximum(m_prev, m_blk)
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    p = jnp.exp(logits - m_safe[:, None])
    p = jnp.where(mask, p, 0.0)

    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    l_scr[...] = l_scr[...] * alpha + p.sum(-1)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-37)
        o_ref[0, :, 0, :] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "attn_softcap", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, q_pos, k_pos, *, window: Optional[int],
                    scale: float, attn_softcap: Optional[float] = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    g = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq, nk = Sq // bq, Sk // bk

    grid = (B, Hq, nq, nk)
    kernel = functools.partial(_kernel, scale=scale,
                               attn_softcap=attn_softcap, window=window,
                               nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, D),
                         lambda b, h, iq, ik, g=g: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, Dv),
                         lambda b, h, iq, ik, g=g: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bq), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, bk), lambda b, h, iq, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dv),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, q_pos, k_pos)
    return out
