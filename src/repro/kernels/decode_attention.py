"""Decode attention Pallas TPU kernel: one query token vs a long KV cache.

This is the kernel form of the paper's K-V-cache pillar (P1): at each
decode step only the new token's attention is computed, streaming cache
blocks HBM->VMEM.  Unlike the prefill kernel, all query heads of a batch
element are processed together (the single query row would waste the MXU
otherwise):

  grid = (B, num_k_blocks)   (k innermost, sequential)

Per step: q tile (Hq, D) stays resident; one (block_k, Hkv, D) cache tile
streams in; GQA grouping is a reshape of the q rows (Hkv, g, D) batched
against the tile.  Running softmax state (m, l, acc) lives in VMEM scratch.

The quantized-pool variant (``paged_decode_attention_q8``) streams int8
K/V pages plus their per-entry fp32 scale rows and dequantizes
*in-register* to fp32 right before QK^T / PV — halving the HBM bytes per
decode step versus bf16 pages while the matmuls still accumulate in fp32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 256


def _attend_block_mq(qg, k, v, mask, m_scr, l_scr, acc_scr, *, scale,
                     attn_softcap):
    """One online-softmax accumulation step shared by every decode /
    verify kernel: ``nq`` query rows per kv head against one fp32 K/V
    tile, each query under its own key mask (causal masking *inside* a
    speculation window is per-query).

    qg: (Hkv, nq, g, D); k/v: (bk, Hkv, D[v]); mask: (nq, bk) bool.
    Scratch state is flattened over (nq, g): m/l (Hkv, nq*g) and acc
    (Hkv, nq*g, Dv) — the single-query kernels are the nq == 1 case.
    """
    Hkv, nq, g, D = qg.shape
    bk = k.shape[0]
    q2 = qg.reshape(Hkv, nq * g, D)
    # (Hkv, nq*g, D) x (bk, Hkv, D) -> (Hkv, nq*g, bk)
    logits = jax.lax.dot_general(
        q2, k, (((2,), (2,)), ((0,), (1,))),
        preferred_element_type=jnp.float32) * scale
    if attn_softcap is not None:
        logits = jnp.tanh(logits / attn_softcap) * attn_softcap
    mask4 = jnp.broadcast_to(mask[None, :, None, :], (Hkv, nq, g, bk)) \
        .reshape(Hkv, nq * g, bk)
    logits = jnp.where(mask4, logits, -jnp.inf)

    m_prev = m_scr[...]                                    # (Hkv, nq*g)
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
    p = jnp.exp(logits - m_safe[..., None])
    p = jnp.where(mask4, p, 0.0)

    # (Hkv, nq*g, bk) x (bk, Hkv, Dv) -> (Hkv, nq*g, Dv)
    pv = jax.lax.dot_general(
        p, v, (((2,), (0,)), ((0,), (1,))),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
    l_scr[...] = l_scr[...] * alpha + p.sum(-1)
    m_scr[...] = m_new


def _attend_block(q, k, v, mask, m_scr, l_scr, acc_scr, *, scale,
                  attn_softcap, g):
    """Single-query case: q (Hq, D) under one (bk,) key mask."""
    Hq, D = q.shape
    Hkv = k.shape[1]
    _attend_block_mq(q.reshape(Hkv, 1, g, D), k, v, mask[None, :],
                     m_scr, l_scr, acc_scr, scale=scale,
                     attn_softcap=attn_softcap)


def shape_supported(q, k, block_k: int = DEFAULT_BLOCK_K) -> bool:
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    return (Sq == 1 and Hq % Hkv == 0 and D % 8 == 0
            and k.shape[3] % 8 == 0 and Sk % min(block_k, Sk) == 0)


def _kernel(q_ref, k_ref, v_ref, kp_ref, qp_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, attn_softcap, window, nk, g):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (Hq, D)
    k = k_ref[0].astype(jnp.float32)                       # (bk, Hkv, D)
    v = v_ref[0].astype(jnp.float32)                       # (bk, Hkv, Dv)
    kp = kp_ref[0]                                         # (bk,)
    qp = qp_ref[0]                                         # (1,)

    mask = (kp <= qp[0]) & (kp >= 0)
    if window is not None:
        mask &= kp > (qp[0] - window)
    _attend_block(q, k, v, mask, m_scr, l_scr, acc_scr, scale=scale,
                  attn_softcap=attn_softcap, g=g)

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-37)[..., None]
        out = (acc_scr[...] / denom).reshape(q.shape[0], acc_scr.shape[-1])
        o_ref[0, 0] = out.astype(o_ref.dtype)


def paged_shape_supported(q, kpool, block_tables) -> bool:
    B, Sq, Hq, D = q.shape
    page, Hkv = kpool.shape[1], kpool.shape[2]
    return (Sq == 1 and Hq % Hkv == 0 and D % 8 == 0
            and kpool.shape[3] % 8 == 0 and page % 8 == 0
            and block_tables.shape[0] == B)


def _paged_kernel(bt_ref, q_ref, k_ref, v_ref, kp_ref, qp_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale, attn_softcap, window,
                  npages, g):
    """Same online-softmax scheme as _kernel, but the grid walks the
    slot's block table: page j streams physical page bt[b, j] from the
    pool (the BlockSpec index_map does the indirection; bt itself arrives
    via scalar prefetch).  Unallocated entries resolve to the dump page,
    whose positions are always -1, so masking alone keeps them out."""
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (Hq, D)
    k = k_ref[0].astype(jnp.float32)                       # (page, Hkv, D)
    v = v_ref[0].astype(jnp.float32)                       # (page, Hkv, Dv)
    kp = kp_ref[0]                                         # (page,)
    qp = qp_ref[0]                                         # (1,)
    allocated = bt_ref[b, j] >= 0

    mask = (kp <= qp[0]) & (kp >= 0) & allocated
    if window is not None:
        mask &= kp > (qp[0] - window)
    _attend_block(q, k, v, mask, m_scr, l_scr, acc_scr, scale=scale,
                  attn_softcap=attn_softcap, g=g)

    @pl.when(j == npages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-37)[..., None]
        out = (acc_scr[...] / denom).reshape(q.shape[0], acc_scr.shape[-1])
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "attn_softcap", "interpret"))
def paged_decode_attention(q, kpool, vpool, ppos, block_tables, q_pos, *,
                           window: Optional[int], scale: float,
                           attn_softcap: Optional[float] = None,
                           interpret: bool = False):
    """Decode attention over a paged KV pool.

    q: (B,1,Hq,D); kpool/vpool: (P,page,Hkv,D[v]); ppos: (P,page) absolute
    positions (-1 empty); block_tables: (B,npages) physical page ids with
    -1 = unallocated; q_pos: (B,1).  Page P-1 is the dump page.
    """
    B, _, Hq, D = q.shape
    P, page, Hkv, Dv = vpool.shape
    npages = block_tables.shape[1]
    g = Hq // Hkv
    dump = P - 1

    def page_of(b, j, bt):
        pid = bt[b, j]
        return jnp.where(pid < 0, dump, pid)

    kernel = functools.partial(_paged_kernel, scale=scale,
                               attn_softcap=attn_softcap, window=window,
                               npages=npages, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, npages),
        in_specs=[
            pl.BlockSpec((1, 1, Hq, D), lambda b, j, bt: (b, 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, Dv),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0, 0)),
            pl.BlockSpec((1, page),
                         lambda b, j, bt: (page_of(b, j, bt), 0)),
            pl.BlockSpec((1, 1), lambda b, j, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hq, Dv), lambda b, j, bt: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, g), jnp.float32),
            pltpu.VMEM((Hkv, g), jnp.float32),
            pltpu.VMEM((Hkv, g, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, Hq, Dv), q.dtype),
        interpret=interpret,
    )(block_tables, q, kpool, vpool, ppos, q_pos)
    return out


def _paged_kernel_q8(bt_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, kp_ref,
                     qp_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
                     attn_softcap, window, npages, g):
    """Quantized-pool variant of _paged_kernel: the page tile arrives as
    int8 codes plus a per-entry (page, Hkv) fp32 scale row, and the
    dequantize (code * scale) happens in-register before QK^T / PV — the
    HBM stream is half the bf16 bytes, the math is still fp32."""
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (Hq, D)
    k = k_ref[0].astype(jnp.float32) \
        * ks_ref[0].astype(jnp.float32)[..., None]         # (page, Hkv, D)
    v = v_ref[0].astype(jnp.float32) \
        * vs_ref[0].astype(jnp.float32)[..., None]         # (page, Hkv, Dv)
    kp = kp_ref[0]                                         # (page,)
    qp = qp_ref[0]                                         # (1,)
    allocated = bt_ref[b, j] >= 0

    mask = (kp <= qp[0]) & (kp >= 0) & allocated
    if window is not None:
        mask &= kp > (qp[0] - window)
    _attend_block(q, k, v, mask, m_scr, l_scr, acc_scr, scale=scale,
                  attn_softcap=attn_softcap, g=g)

    @pl.when(j == npages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-37)[..., None]
        out = (acc_scr[...] / denom).reshape(q.shape[0], acc_scr.shape[-1])
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "attn_softcap", "interpret"))
def paged_decode_attention_q8(q, kpool, k_scale, vpool, v_scale, ppos,
                              block_tables, q_pos, *,
                              window: Optional[int], scale: float,
                              attn_softcap: Optional[float] = None,
                              interpret: bool = False):
    """Decode attention over an int8-quantized paged KV pool.

    Same contract as :func:`paged_decode_attention` plus the parallel
    scale pools: kpool/vpool are (P,page,Hkv,D[v]) int8 codes and
    k_scale/v_scale are (P,page,Hkv) fp32 per-entry absmax scales.
    Dequantization is fused into the page stream (in-register, before
    the matmuls)."""
    B, _, Hq, D = q.shape
    P, page, Hkv, Dv = vpool.shape
    npages = block_tables.shape[1]
    g = Hq // Hkv
    dump = P - 1

    def page_of(b, j, bt):
        pid = bt[b, j]
        return jnp.where(pid < 0, dump, pid)

    kernel = functools.partial(_paged_kernel_q8, scale=scale,
                               attn_softcap=attn_softcap, window=window,
                               npages=npages, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, npages),
        in_specs=[
            pl.BlockSpec((1, 1, Hq, D), lambda b, j, bt: (b, 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0)),
            pl.BlockSpec((1, page, Hkv, Dv),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0)),
            pl.BlockSpec((1, page),
                         lambda b, j, bt: (page_of(b, j, bt), 0)),
            pl.BlockSpec((1, 1), lambda b, j, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hq, Dv), lambda b, j, bt: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, g), jnp.float32),
            pltpu.VMEM((Hkv, g), jnp.float32),
            pltpu.VMEM((Hkv, g, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, Hq, Dv), q.dtype),
        interpret=interpret,
    )(block_tables, q, kpool, k_scale, vpool, v_scale, ppos, q_pos)
    return out


def paged_mixed_shape_supported(q, kpool, block_tables) -> bool:
    B, Sq, Hq, D = q.shape
    page, Hkv = kpool.shape[1], kpool.shape[2]
    return (Sq >= 1 and Hq % Hkv == 0 and D % 8 == 0
            and kpool.shape[3] % 8 == 0 and page % 8 == 0
            and block_tables.shape[0] == B)


# verify is the all-rows-full special case of the mixed entry below
paged_verify_shape_supported = paged_mixed_shape_supported


def _mq_mask(kp, qp, allocated, window):
    """(W, page) per-query key mask for one streamed page tile: causal
    against the stored absolute positions — which the mixed/verify
    forward has just written for the window's own tokens too, so query j
    attends tokens 1..j-1 of its window (causality *inside* a prefill
    chunk or speculation window) for free.  A padding query carries
    qp == -1: no key satisfies ``kp <= -1 & kp >= 0``, so its row is
    fully masked and the kernel's zero-denominator guard emits zeros."""
    mask = (kp[None, :] <= qp[:, None]) & (kp >= 0)[None, :] & allocated
    if window is not None:
        mask &= kp[None, :] > (qp[:, None] - window)
    return mask


def _paged_mixed_kernel(bt_ref, q_ref, k_ref, v_ref, kp_ref, qp_ref, o_ref,
                        m_scr, l_scr, acc_scr, *, scale, attn_softcap,
                        window, npages, g):
    """Multi-query-per-slot variant of _paged_kernel: all W query
    positions of a slot's window (prefill chunk, speculation window, or
    a single decode token plus padding) stream the slot's pages ONCE
    (the block-table indirection and online-softmax scheme are
    identical; scratch carries an extra query dim folded into g)."""
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                       # (K1, Hq, D)
    k = k_ref[0].astype(jnp.float32)                       # (page, Hkv, D)
    v = v_ref[0].astype(jnp.float32)                       # (page, Hkv, Dv)
    kp = kp_ref[0]                                         # (page,)
    qp = qp_ref[0]                                         # (K1,)
    K1, Hq, D = q.shape
    Hkv = k.shape[1]

    mask = _mq_mask(kp, qp, bt_ref[b, j] >= 0, window)
    qg = q.reshape(K1, Hkv, g, D).transpose(1, 0, 2, 3)    # (Hkv, K1, g, D)
    _attend_block_mq(qg, k, v, mask, m_scr, l_scr, acc_scr, scale=scale,
                     attn_softcap=attn_softcap)

    @pl.when(j == npages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-37)[..., None]
        out = (acc_scr[...] / denom) \
            .reshape(Hkv, K1, g, acc_scr.shape[-1]) \
            .transpose(1, 0, 2, 3).reshape(K1, Hq, acc_scr.shape[-1])
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "attn_softcap", "interpret"))
def paged_mixed_attention(q, kpool, vpool, ppos, block_tables, q_pos, *,
                          window: Optional[int], scale: float,
                          attn_softcap: Optional[float] = None,
                          interpret: bool = False):
    """Variable-length mixed-batch attention over a paged KV pool: up to
    W query positions per slot in one kernel pass, with *per-slot query
    counts* — 1 real query for decode rows, chunk-length queries for
    chunked-prefill rows, K+1 for speculative verify windows.

    Same contract as :func:`paged_decode_attention` with the query dim
    widened: q (B, W, Hq, D), q_pos (B, W) absolute positions where
    **-1 marks a padding query** (its output lane is zeros; callers
    discard it).  The window's own K/V must already be in the pool
    (written by ``kv_cache.paged_write_decode_multi``); stored positions
    make the per-query causal mask exact inside the window, so any
    chunk boundary is legal.
    """
    B, K1, Hq, D = q.shape
    P, page, Hkv, Dv = vpool.shape
    npages = block_tables.shape[1]
    g = Hq // Hkv
    dump = P - 1

    def page_of(b, j, bt):
        pid = bt[b, j]
        return jnp.where(pid < 0, dump, pid)

    kernel = functools.partial(_paged_mixed_kernel, scale=scale,
                               attn_softcap=attn_softcap, window=window,
                               npages=npages, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, npages),
        in_specs=[
            pl.BlockSpec((1, K1, Hq, D), lambda b, j, bt: (b, 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, Dv),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0, 0)),
            pl.BlockSpec((1, page),
                         lambda b, j, bt: (page_of(b, j, bt), 0)),
            pl.BlockSpec((1, K1), lambda b, j, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, K1, Hq, Dv),
                               lambda b, j, bt: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, K1 * g), jnp.float32),
            pltpu.VMEM((Hkv, K1 * g), jnp.float32),
            pltpu.VMEM((Hkv, K1 * g, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K1, Hq, Dv), q.dtype),
        interpret=interpret,
    )(block_tables, q, kpool, vpool, ppos, q_pos)
    return out


# speculative verify = the mixed entry with every row's window full
paged_verify_attention = paged_mixed_attention


def _paged_mixed_kernel_q8(bt_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                           kp_ref, qp_ref, o_ref, m_scr, l_scr, acc_scr,
                           *, scale, attn_softcap, window, npages, g):
    """Quantized-pool mixed kernel: int8 page tiles + per-entry scale
    rows dequantized in-register (exactly _paged_kernel_q8's stream)
    feeding the multi-query online-softmax body."""
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                       # (K1, Hq, D)
    k = k_ref[0].astype(jnp.float32) \
        * ks_ref[0].astype(jnp.float32)[..., None]         # (page, Hkv, D)
    v = v_ref[0].astype(jnp.float32) \
        * vs_ref[0].astype(jnp.float32)[..., None]         # (page, Hkv, Dv)
    kp = kp_ref[0]
    qp = qp_ref[0]
    K1, Hq, D = q.shape
    Hkv = k.shape[1]

    mask = _mq_mask(kp, qp, bt_ref[b, j] >= 0, window)
    qg = q.reshape(K1, Hkv, g, D).transpose(1, 0, 2, 3)
    _attend_block_mq(qg, k, v, mask, m_scr, l_scr, acc_scr, scale=scale,
                     attn_softcap=attn_softcap)

    @pl.when(j == npages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-37)[..., None]
        out = (acc_scr[...] / denom) \
            .reshape(Hkv, K1, g, acc_scr.shape[-1]) \
            .transpose(1, 0, 2, 3).reshape(K1, Hq, acc_scr.shape[-1])
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "attn_softcap", "interpret"))
def paged_mixed_attention_q8(q, kpool, k_scale, vpool, v_scale, ppos,
                             block_tables, q_pos, *,
                             window: Optional[int], scale: float,
                             attn_softcap: Optional[float] = None,
                             interpret: bool = False):
    """:func:`paged_mixed_attention` over an int8-quantized pool (same
    scale-pool contract as :func:`paged_decode_attention_q8`; q_pos of
    -1 marks padding queries exactly like the fp entry)."""
    B, K1, Hq, D = q.shape
    P, page, Hkv, Dv = vpool.shape
    npages = block_tables.shape[1]
    g = Hq // Hkv
    dump = P - 1

    def page_of(b, j, bt):
        pid = bt[b, j]
        return jnp.where(pid < 0, dump, pid)

    kernel = functools.partial(_paged_mixed_kernel_q8, scale=scale,
                               attn_softcap=attn_softcap, window=window,
                               npages=npages, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, npages),
        in_specs=[
            pl.BlockSpec((1, K1, Hq, D), lambda b, j, bt: (b, 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0)),
            pl.BlockSpec((1, page, Hkv, Dv),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv),
                         lambda b, j, bt: (page_of(b, j, bt), 0, 0)),
            pl.BlockSpec((1, page),
                         lambda b, j, bt: (page_of(b, j, bt), 0)),
            pl.BlockSpec((1, K1), lambda b, j, bt: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, K1, Hq, Dv),
                               lambda b, j, bt: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, K1 * g), jnp.float32),
            pltpu.VMEM((Hkv, K1 * g), jnp.float32),
            pltpu.VMEM((Hkv, K1 * g, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K1, Hq, Dv), q.dtype),
        interpret=interpret,
    )(block_tables, q, kpool, k_scale, vpool, v_scale, ppos, q_pos)
    return out


paged_verify_attention_q8 = paged_mixed_attention_q8


PACKED_BLOCK_Q = 8
# the whole (1, T, Hq, D[v]) query/output blocks stay VMEM-resident
# (constant index maps), so T is capped by a VMEM budget, not the grid
PACKED_VMEM_BYTES = 8 * 1024 * 1024


def paged_packed_shape_supported(q, kpool, block_tables,
                                 meta=None) -> bool:
    _, T, Hq, D = q.shape
    page, Hkv, Dv = kpool.shape[1], kpool.shape[2], kpool.shape[3]
    return (q.shape[0] == 1 and T >= PACKED_BLOCK_Q
            and T % PACKED_BLOCK_Q == 0 and Hq % Hkv == 0
            and D % 8 == 0 and Dv % 8 == 0 and page % 8 == 0
            and T * Hq * (D + Dv) * 4 <= PACKED_VMEM_BYTES)


def packed_meta_table(seg_starts, seg_lengths, seg_slots, n_tokens,
                      n_work):
    """Host-side helper: cut each packed segment into PACKED_BLOCK_Q-wide
    query windows and emit the (n_work, 4) int32 work table the packed
    kernel walks — rows ``(slot, tile_start, win_start, win_end)`` in
    global stream coordinates, where tile_start is the window's start
    clamped to ``n_tokens - PACKED_BLOCK_Q`` so the fixed-width q tile
    never reads past the stream.  Unused rows carry slot = -1 (fully
    masked no-ops)."""
    import numpy as np
    bq = PACKED_BLOCK_Q
    meta = np.full((n_work, 4), -1, np.int32)
    meta[:, 1:] = 0
    w = 0
    for s0, ln, slot in zip(seg_starts, seg_lengths, seg_slots):
        for blk in range(0, int(ln), bq):
            ws = int(s0) + blk
            we = min(int(s0) + int(ln), ws + bq)
            meta[w] = (int(slot), min(ws, n_tokens - bq), ws, we)
            w += 1
    assert w <= n_work, "packed meta overflow: raise n_work"
    return meta


def _paged_packed_kernel(meta_ref, bt_ref, q_ref, k_ref, v_ref, kp_ref,
                         qp_ref, o_ref, m_scr, l_scr, acc_scr, *, scale,
                         attn_softcap, window, npages, g):
    """Token-packed ragged variant of _paged_mixed_kernel: the grid's
    first dim walks *query windows* of the flat (1, T) stream instead of
    slots.  Work item w covers PACKED_BLOCK_Q stream lanes starting at
    meta[w, 1]; only lanes inside [meta[w, 2], meta[w, 3]) belong to the
    window's segment — the rest are masked off and their output lanes
    preserved via a masked read-modify-write at finalize (grid items run
    sequentially, and a lane's owning window is unique, so the RMW never
    races).  The streamed pages are the *segment's slot's* pages
    (meta[w, 0] indexes the block table); each query lane masks keys
    against its own absolute position, so every token of every slot gets
    its exact causal paged-attention in ONE kernel launch."""
    w, j = pl.program_id(0), pl.program_id(1)
    bq = PACKED_BLOCK_Q
    slot = meta_ref[w, 0]
    offc = meta_ref[w, 1]
    ws = meta_ref[w, 2]
    we = meta_ref[w, 3]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, pl.ds(offc, bq)].astype(jnp.float32)      # (bq, Hq, D)
    k = k_ref[0].astype(jnp.float32)                       # (page, Hkv, D)
    v = v_ref[0].astype(jnp.float32)                       # (page, Hkv, Dv)
    kp = kp_ref[0]                                         # (page,)
    qp = qp_ref[0, pl.ds(offc, bq)]                        # (bq,)
    _, Hq, D = q.shape
    Hkv = k.shape[1]

    lane = offc + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    in_win = (lane >= ws) & (lane < we)
    qp_eff = jnp.where(in_win, qp, -1)
    allocated = (slot >= 0) & (bt_ref[jnp.maximum(slot, 0), j] >= 0)
    mask = _mq_mask(kp, qp_eff, allocated, window)
    qg = q.reshape(bq, Hkv, g, D).transpose(1, 0, 2, 3)    # (Hkv, bq, g, D)
    _attend_block_mq(qg, k, v, mask, m_scr, l_scr, acc_scr, scale=scale,
                     attn_softcap=attn_softcap)

    @pl.when(j == npages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-37)[..., None]
        out = (acc_scr[...] / denom) \
            .reshape(Hkv, bq, g, acc_scr.shape[-1]) \
            .transpose(1, 0, 2, 3).reshape(bq, Hq, acc_scr.shape[-1])
        old = o_ref[0, pl.ds(offc, bq)]
        o_ref[0, pl.ds(offc, bq)] = jnp.where(
            in_win[:, None, None], out.astype(o_ref.dtype), old)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "attn_softcap", "interpret"))
def paged_packed_attention(q, kpool, vpool, ppos, block_tables, q_pos,
                           meta, *, window: Optional[int], scale: float,
                           attn_softcap: Optional[float] = None,
                           interpret: bool = False):
    """Token-packed ragged attention over a paged KV pool: the whole
    mixed iteration — every decode token and every prefill-chunk token of
    every slot — as ONE (1, T) dispatch.

    q: (1, T, Hq, D) flat token stream; q_pos: (1, T) absolute positions
    (-1 = padding lane, comes back zeros); block_tables: (slots, npages);
    meta: (n_work, 4) int32 work table from :func:`packed_meta_table`.
    The stream's own K/V must already be in the pool (written by
    ``kv_cache.paged_write_packed``); stored absolute positions give each
    query its exact causal mask over its own slot's history, including
    earlier tokens of its own chunk."""
    _, T, Hq, D = q.shape
    P, page, Hkv, Dv = vpool.shape
    npages = block_tables.shape[1]
    n_work = meta.shape[0]
    g = Hq // Hkv
    dump = P - 1
    bq = PACKED_BLOCK_Q

    def page_of(w, j, meta, bt):
        slot = meta[w, 0]
        pid = bt[jnp.maximum(slot, 0), j]
        return jnp.where((slot < 0) | (pid < 0), dump, pid)

    kernel = functools.partial(_paged_packed_kernel, scale=scale,
                               attn_softcap=attn_softcap, window=window,
                               npages=npages, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_work, npages),
        in_specs=[
            pl.BlockSpec((1, T, Hq, D), lambda w, j, meta, bt: (0, 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda w, j, meta, bt: (page_of(w, j, meta, bt),
                                                 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, Dv),
                         lambda w, j, meta, bt: (page_of(w, j, meta, bt),
                                                 0, 0, 0)),
            pl.BlockSpec((1, page),
                         lambda w, j, meta, bt: (page_of(w, j, meta, bt),
                                                 0)),
            pl.BlockSpec((1, T), lambda w, j, meta, bt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, Hq, Dv),
                               lambda w, j, meta, bt: (0, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, bq * g), jnp.float32),
            pltpu.VMEM((Hkv, bq * g), jnp.float32),
            pltpu.VMEM((Hkv, bq * g, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, T, Hq, Dv), q.dtype),
        interpret=interpret,
    )(meta, block_tables, q, kpool, vpool, ppos, q_pos)
    # lanes no window owns (stream padding) are never written: zero them
    return jnp.where((q_pos >= 0)[..., None, None], out, 0)


def _paged_packed_kernel_q8(meta_ref, bt_ref, q_ref, k_ref, ks_ref, v_ref,
                            vs_ref, kp_ref, qp_ref, o_ref, m_scr, l_scr,
                            acc_scr, *, scale, attn_softcap, window,
                            npages, g):
    """Quantized-pool packed kernel: int8 page tiles + per-entry scale
    rows dequantized in-register, feeding the same windowed
    online-softmax body as _paged_packed_kernel."""
    w, j = pl.program_id(0), pl.program_id(1)
    bq = PACKED_BLOCK_Q
    slot = meta_ref[w, 0]
    offc = meta_ref[w, 1]
    ws = meta_ref[w, 2]
    we = meta_ref[w, 3]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, pl.ds(offc, bq)].astype(jnp.float32)      # (bq, Hq, D)
    k = k_ref[0].astype(jnp.float32) \
        * ks_ref[0].astype(jnp.float32)[..., None]         # (page, Hkv, D)
    v = v_ref[0].astype(jnp.float32) \
        * vs_ref[0].astype(jnp.float32)[..., None]         # (page, Hkv, Dv)
    kp = kp_ref[0]
    qp = qp_ref[0, pl.ds(offc, bq)]
    _, Hq, D = q.shape
    Hkv = k.shape[1]

    lane = offc + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]
    in_win = (lane >= ws) & (lane < we)
    qp_eff = jnp.where(in_win, qp, -1)
    allocated = (slot >= 0) & (bt_ref[jnp.maximum(slot, 0), j] >= 0)
    mask = _mq_mask(kp, qp_eff, allocated, window)
    qg = q.reshape(bq, Hkv, g, D).transpose(1, 0, 2, 3)
    _attend_block_mq(qg, k, v, mask, m_scr, l_scr, acc_scr, scale=scale,
                     attn_softcap=attn_softcap)

    @pl.when(j == npages - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-37)[..., None]
        out = (acc_scr[...] / denom) \
            .reshape(Hkv, bq, g, acc_scr.shape[-1]) \
            .transpose(1, 0, 2, 3).reshape(bq, Hq, acc_scr.shape[-1])
        old = o_ref[0, pl.ds(offc, bq)]
        o_ref[0, pl.ds(offc, bq)] = jnp.where(
            in_win[:, None, None], out.astype(o_ref.dtype), old)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "attn_softcap", "interpret"))
def paged_packed_attention_q8(q, kpool, k_scale, vpool, v_scale, ppos,
                              block_tables, q_pos, meta, *,
                              window: Optional[int], scale: float,
                              attn_softcap: Optional[float] = None,
                              interpret: bool = False):
    """:func:`paged_packed_attention` over an int8-quantized pool (same
    scale-pool contract as :func:`paged_decode_attention_q8`)."""
    _, T, Hq, D = q.shape
    P, page, Hkv, Dv = vpool.shape
    npages = block_tables.shape[1]
    n_work = meta.shape[0]
    g = Hq // Hkv
    dump = P - 1
    bq = PACKED_BLOCK_Q

    def page_of(w, j, meta, bt):
        slot = meta[w, 0]
        pid = bt[jnp.maximum(slot, 0), j]
        return jnp.where((slot < 0) | (pid < 0), dump, pid)

    kernel = functools.partial(_paged_packed_kernel_q8, scale=scale,
                               attn_softcap=attn_softcap, window=window,
                               npages=npages, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_work, npages),
        in_specs=[
            pl.BlockSpec((1, T, Hq, D), lambda w, j, meta, bt: (0, 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv, D),
                         lambda w, j, meta, bt: (page_of(w, j, meta, bt),
                                                 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv),
                         lambda w, j, meta, bt: (page_of(w, j, meta, bt),
                                                 0, 0)),
            pl.BlockSpec((1, page, Hkv, Dv),
                         lambda w, j, meta, bt: (page_of(w, j, meta, bt),
                                                 0, 0, 0)),
            pl.BlockSpec((1, page, Hkv),
                         lambda w, j, meta, bt: (page_of(w, j, meta, bt),
                                                 0, 0)),
            pl.BlockSpec((1, page),
                         lambda w, j, meta, bt: (page_of(w, j, meta, bt),
                                                 0)),
            pl.BlockSpec((1, T), lambda w, j, meta, bt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, Hq, Dv),
                               lambda w, j, meta, bt: (0, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, bq * g), jnp.float32),
            pltpu.VMEM((Hkv, bq * g), jnp.float32),
            pltpu.VMEM((Hkv, bq * g, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, T, Hq, Dv), q.dtype),
        interpret=interpret,
    )(meta, block_tables, q, kpool, k_scale, vpool, v_scale, ppos, q_pos)
    return jnp.where((q_pos >= 0)[..., None, None], out, 0)


@functools.partial(jax.jit, static_argnames=("window", "scale",
                                             "attn_softcap", "block_k",
                                             "interpret"))
def decode_attention(q, k, v, k_pos, q_pos, *, window: Optional[int],
                     scale: float, attn_softcap: Optional[float] = None,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False):
    """q: (B,1,Hq,D), k/v: (B,Sk,Hkv,Dv), k_pos: (B,Sk), q_pos: (B,1)."""
    B, _, Hq, D = q.shape
    Sk, Hkv, Dv = k.shape[1], k.shape[2], v.shape[3]
    g = Hq // Hkv
    bk = min(block_k, Sk)
    nk = Sk // bk

    kernel = functools.partial(_kernel, scale=scale,
                               attn_softcap=attn_softcap, window=window,
                               nk=nk, g=g)
    out = pl.pallas_call(
        kernel,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, 1, Hq, D), lambda b, ik: (b, 0, 0, 0)),
            pl.BlockSpec((1, bk, Hkv, D), lambda b, ik: (b, ik, 0, 0)),
            pl.BlockSpec((1, bk, Hkv, Dv), lambda b, ik: (b, ik, 0, 0)),
            pl.BlockSpec((1, bk), lambda b, ik: (b, ik)),
            pl.BlockSpec((1, 1), lambda b, ik: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hq, Dv), lambda b, ik: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1, Hq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hkv, g), jnp.float32),
            pltpu.VMEM((Hkv, g), jnp.float32),
            pltpu.VMEM((Hkv, g, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, k_pos, q_pos)
    return out
