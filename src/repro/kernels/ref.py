"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.3819763e38


def _mask(q_pos, k_pos, window):
    m = (k_pos[:, None, :] <= q_pos[:, :, None]) & (k_pos[:, None, :] >= 0)
    if window is not None:
        m &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    return m


def flash_attention_ref(q, k, v, q_pos, k_pos, *, window: Optional[int],
                        scale: float, attn_softcap: Optional[float] = None):
    """q: (B,Sq,Hq,D), k/v: (B,Sk,Hkv,D) -> (B,Sq,Hq,Dv)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if attn_softcap is not None:
        logits = jnp.tanh(logits / attn_softcap) * attn_softcap
    mask = _mask(q_pos, k_pos, window)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(mask.any(-1)[:, None, None, :, None], p, 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, v.shape[-1]).astype(q.dtype)


def decode_attention_ref(q, k, v, k_pos, q_pos, *, window: Optional[int],
                         scale: float, attn_softcap: Optional[float] = None):
    """q: (B,1,Hq,D) vs cache k/v: (B,Sk,Hkv,D) -> (B,1,Hq,Dv)."""
    return flash_attention_ref(q, k, v, q_pos, k_pos, window=window,
                               scale=scale, attn_softcap=attn_softcap)


def paged_decode_attention_ref(q, kpool, vpool, ppos, block_tables, q_pos, *,
                               window: Optional[int], scale: float,
                               attn_softcap: Optional[float] = None,
                               k_scale=None, v_scale=None):
    """Dense-gather oracle for the paged decode kernel: resolve each slot's
    block table into a contiguous (B, npages*page, ...) view (the same
    ``kv_cache.paged_gather`` the production fallback uses), then run the
    dense decode reference.  With ``k_scale``/``v_scale`` the pools hold
    int8 codes and the gather dequantizes them — the fp32 target the
    fused-dequant Pallas kernel must match."""
    from repro.core.kv_cache import paged_gather
    pool = {"pk": kpool, "pv": vpool, "ppos": ppos}
    if k_scale is not None:
        pool["pk_scale"] = k_scale
        pool["pv_scale"] = v_scale
    k, v, kp = paged_gather(pool, block_tables)
    return decode_attention_ref(q, k.astype(q.dtype), v.astype(q.dtype),
                                kp, q_pos, window=window,
                                scale=scale, attn_softcap=attn_softcap)


def paged_mixed_attention_ref(q, kpool, vpool, ppos, block_tables, q_pos,
                              *, window: Optional[int], scale: float,
                              attn_softcap: Optional[float] = None,
                              k_scale=None, v_scale=None):
    """Oracle for the multi-query paged *mixed* kernel (chunked prefill
    rows, decode rows, speculative verify windows): q (B, W, Hq, D) with
    per-slot query counts expressed through q_pos (B, W) — real queries
    carry absolute positions, padding queries carry -1 and come back as
    zeros.  Causal masking inside a window falls out of the stored
    absolute positions — the window's own K/V are already in the pool
    when it attends.  Shares the dense-gather + flash reference with the
    single-query oracle (which is the W == 1 case)."""
    return paged_decode_attention_ref(
        q, kpool, vpool, ppos, block_tables, q_pos, window=window,
        scale=scale, attn_softcap=attn_softcap, k_scale=k_scale,
        v_scale=v_scale)


# speculative verify = the mixed oracle with every row's window full
paged_verify_attention_ref = paged_mixed_attention_ref


def paged_packed_attention_ref(q, kpool, vpool, ppos, block_tables, q_pos,
                               slot_ids, *, window: Optional[int],
                               scale: float,
                               attn_softcap: Optional[float] = None,
                               k_scale=None, v_scale=None):
    """Oracle for the token-packed ragged kernel: q (1, T, Hq, D) is one
    flat stream where token t belongs to slot ``slot_ids[t]`` and attends
    that slot's paged history only.  Gathers each *slot's* pages densely
    once (exactly ``paged_gather``), then runs every stream token as its
    own single-query attention against its slot's gathered context —
    same key order and count per query as the bucketed per-slot
    fallback, so greedy outputs stay bit-identical across the two paths.
    Padding lanes (slot_ids == -1) come back as zeros."""
    from repro.core.kv_cache import paged_gather
    pool = {"pk": kpool, "pv": vpool, "ppos": ppos}
    if k_scale is not None:
        pool["pk_scale"] = k_scale
        pool["pv_scale"] = v_scale
    k, v, kp = paged_gather(pool, block_tables)     # (B, ctx, H, D)
    B = block_tables.shape[0]
    _, T, Hq, _ = q.shape
    sid = slot_ids.reshape(T)
    safe = jnp.clip(sid, 0, B - 1)
    k_t = k[safe]                                   # (T, ctx, Hkv, D)
    v_t = v[safe]
    kp_t = jnp.where((sid >= 0)[:, None], kp[safe], -1)
    out = decode_attention_ref(
        q.reshape(T, 1, Hq, -1), k_t.astype(q.dtype), v_t.astype(q.dtype),
        kp_t, q_pos.reshape(T, 1), window=window, scale=scale,
        attn_softcap=attn_softcap)
    return out.reshape(1, T, Hq, -1)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + w.astype(jnp.float32))).astype(dt)


def quant_matmul_ref(x, q, s):
    """Weight-quantized matmul oracle: x (..., K) @ int8 q (K, N) with
    per-output-channel fp32 scales s (N,).  Accumulates the codes in
    fp32 and rescales the product — the exact per-column identity
    ``x @ (q * s) == (x @ q) * s`` the fused kernel exploits.  Returns
    fp32; this is also the serve path's jnp fallback (kernel mode off),
    so CPU tier-1 runs the same math the kernel computes."""
    acc = jnp.matmul(x.astype(jnp.float32), q.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return acc * s.astype(jnp.float32)
