"""Fused RMSNorm Pallas TPU kernel.

The paper's "fine-grained OP fusion" (P3, Paddle horizontal/vertical
fusion): square-mean, rsqrt and scale fused into one VMEM pass over each
row tile instead of four HBM round trips.

  grid = (num_row_blocks,) over the flattened (rows, D) view.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def shape_supported(x, block_rows: int = DEFAULT_BLOCK_ROWS) -> bool:
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    return x.shape[-1] % 8 == 0 and rows % min(block_rows, rows) == 0


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    w = w_ref[...].astype(jnp.float32)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * (1.0 + w)[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def fused_rmsnorm(x, w, *, eps: float = 1e-6,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: bool = False):
    shape = x.shape
    D = shape[-1]
    rows = x.size // D
    xf = x.reshape(rows, D)
    br = min(block_rows, rows)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(xf, w)
    return out.reshape(shape)
