"""Weight-quantized matmul Pallas TPU kernel: ``x @ W_q8`` with fused dequant.

The serve path's dense matmuls (attention qkv/out, dense FFN, unembed)
are weight-bound during autoregressive decode: each step streams the
whole weight matrix from HBM for a handful of activation rows.  Storing
weights as int8 codes + per-output-channel fp32 absmax scales
(``precision.quantize_weights``) halves that traffic; this kernel keeps
the halving all the way into the MXU by loading the int8 tile directly
and folding dequantization into the accumulation epilogue.

Per-*column* scales make the rescale exact:

    x @ (q * s)  ==  (x @ q) * s        (column by column)

so the kernel accumulates ``x_f32 @ q_f32`` tiles in a VMEM fp32 scratch
over the K grid dimension and multiplies by the (1, bn) scale tile once,
on the last K step — one multiply per output element instead of one per
weight element, and no dequantized weight copy ever materializes.

  grid = (M/bm, N/bn, K/bk)      (k innermost, sequential)

The public wrapper zero-pads every dimension up to tile multiples (K
padding contributes exact zero products; M/N padding is sliced off), so
arbitrary shapes — non-multiple d_model, odd token counts, small vocabs
— all lower to the same aligned kernel.  Math matches
``ref.quant_matmul_ref`` to fp32 accumulation-order tolerance.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# int8 operands need (32, 128) tiles on the sublane/lane axes; fp32
# needs (8, 128).  bm=32/bk=128/bn=128 satisfies every operand: x tile
# (bm, bk) fp32, q tile (bk, bn) int8, out tile (bm, bn) fp32.
BLOCK_M = 32
BLOCK_K = 128
BLOCK_N = 128

# Guard against pathological padding blowup: a (1, K) decode activation
# against a huge weight is fine (M pads 1 -> 32), but refuse shapes the
# pad-to-tile wrapper would inflate by more than this factor in FLOPs.
MAX_PAD_RATIO = 64.0


def shape_supported(x, q, s) -> bool:
    """x (..., K) fp, q (K, N) int8, s (N,) fp32 — the per-repeat slice
    layout every serve-path call site produces (scan slices stacked
    weights down to 2-D)."""
    if q.ndim != 2 or s.ndim != 1 or x.ndim < 2:
        return False
    K, N = q.shape
    if x.shape[-1] != K or s.shape[0] != N or q.dtype != jnp.int8:
        return False
    M = 1
    for d in x.shape[:-1]:
        M *= d
    if M == 0 or K == 0 or N == 0:
        return False
    mp = -(-M // BLOCK_M) * BLOCK_M
    kp = -(-K // BLOCK_K) * BLOCK_K
    np_ = -(-N // BLOCK_N) * BLOCK_N
    return (mp * kp * np_) <= MAX_PAD_RATIO * (M * K * N)


def _kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, n_k):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # int8 codes dequantize in-register: widened to fp32 on the load
    # path, scaled once in the epilogue (per-column identity above)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), q_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _finish():
        o_ref[...] = acc_ref[...] * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quant_matmul(x, q, s, *, interpret: bool = False):
    """``x @ (q * s)`` in fp32: x (..., K) any float dtype, q (K, N)
    int8 codes, s (N,) fp32 per-output-channel scales.  Returns
    (..., N) fp32 (callers cast back to their compute dtype)."""
    lead = x.shape[:-1]
    K, N = q.shape
    xm = x.reshape(-1, K).astype(jnp.float32)
    M = xm.shape[0]
    mp = -(-M // BLOCK_M) * BLOCK_M
    kp = -(-K // BLOCK_K) * BLOCK_K
    np_ = -(-N // BLOCK_N) * BLOCK_N
    if (mp, kp) != (M, K):
        xm = jnp.pad(xm, ((0, mp - M), (0, kp - K)))
    if (kp, np_) != (K, N):
        q = jnp.pad(q, ((0, kp - K), (0, np_ - N)))
    s2 = s.astype(jnp.float32).reshape(1, N)
    if np_ != N:
        s2 = jnp.pad(s2, ((0, 0), (0, np_ - N)))
    n_k = kp // BLOCK_K

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(mp // BLOCK_M, np_ // BLOCK_N, n_k),
        in_specs=[
            pl.BlockSpec((BLOCK_M, BLOCK_K), lambda i, j, k: (i, k)),
            pl.BlockSpec((BLOCK_K, BLOCK_N), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, BLOCK_N), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BLOCK_M, BLOCK_N), jnp.float32)],
        interpret=interpret,
    )(xm, q, s2)
    return out[:M, :N].reshape(lead + (N,))
