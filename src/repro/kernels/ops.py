"""Jit'd wrappers + runtime dispatch for the Pallas kernels.

Kernel modes:
  * "off"       — pure-jnp paths only (default on CPU; also the dry-run
                  lowering path so cost_analysis sees real HLO FLOPs).
  * "interpret" — Pallas kernels in interpret mode (CPU correctness runs).
  * "tpu"       — compiled Pallas kernels (real hardware).
"""
from __future__ import annotations

import contextlib
from typing import Optional

_MODE = "off"


def kernel_mode() -> str:
    return _MODE


def set_kernel_mode(mode: str) -> None:
    global _MODE
    assert mode in ("off", "interpret", "tpu"), mode
    _MODE = mode


@contextlib.contextmanager
def kernel_mode_ctx(mode: str):
    prev = kernel_mode()
    set_kernel_mode(mode)
    try:
        yield
    finally:
        set_kernel_mode(prev)


# ---------------------------------------------------------------------------
# Dispatchers (return None -> caller falls back to the jnp reference)
# ---------------------------------------------------------------------------


def maybe_flash_attention(q, k, v, q_pos, k_pos, *, window, scale,
                          attn_softcap=None):
    if _MODE == "off":
        return None
    from repro.kernels import flash_attention as FA
    if not FA.shape_supported(q, k):
        return None
    return FA.flash_attention(q, k, v, q_pos, k_pos, window=window,
                              scale=scale, attn_softcap=attn_softcap,
                              interpret=(_MODE == "interpret"))


def maybe_decode_attention(q, k, v, k_pos, q_pos, *, window, scale,
                           attn_softcap=None):
    if _MODE == "off":
        return None
    from repro.kernels import decode_attention as DA
    if not DA.shape_supported(q, k):
        return None
    return DA.decode_attention(q, k, v, k_pos, q_pos, window=window,
                               scale=scale, attn_softcap=attn_softcap,
                               interpret=(_MODE == "interpret"))


def maybe_paged_decode_attention(q, kpool, vpool, ppos, block_tables, q_pos,
                                 *, window, scale, attn_softcap=None,
                                 k_scale=None, v_scale=None):
    if _MODE == "off":
        return None
    from repro.kernels import decode_attention as DA
    if not DA.paged_shape_supported(q, kpool, block_tables):
        return None
    if k_scale is not None:
        # int8 pool: dequantization fused into the page stream
        return DA.paged_decode_attention_q8(
            q, kpool, k_scale, vpool, v_scale, ppos, block_tables, q_pos,
            window=window, scale=scale, attn_softcap=attn_softcap,
            interpret=(_MODE == "interpret"))
    return DA.paged_decode_attention(q, kpool, vpool, ppos, block_tables,
                                     q_pos, window=window, scale=scale,
                                     attn_softcap=attn_softcap,
                                     interpret=(_MODE == "interpret"))


def maybe_paged_mixed_attention(q, kpool, vpool, ppos, block_tables, q_pos,
                                *, window, scale, attn_softcap=None,
                                k_scale=None, v_scale=None):
    """Multi-query paged attention with per-slot variable query counts:
    q (B, W, Hq, D) / q_pos (B, W) score a whole per-slot window —
    prefill chunk, speculation window, or a lone decode token — in one
    kernel pass; q_pos == -1 marks padding queries (zero outputs)."""
    if _MODE == "off":
        return None
    from repro.kernels import decode_attention as DA
    if not DA.paged_mixed_shape_supported(q, kpool, block_tables):
        return None
    if k_scale is not None:
        return DA.paged_mixed_attention_q8(
            q, kpool, k_scale, vpool, v_scale, ppos, block_tables, q_pos,
            window=window, scale=scale, attn_softcap=attn_softcap,
            interpret=(_MODE == "interpret"))
    return DA.paged_mixed_attention(q, kpool, vpool, ppos, block_tables,
                                    q_pos, window=window, scale=scale,
                                    attn_softcap=attn_softcap,
                                    interpret=(_MODE == "interpret"))


# speculative verify = the mixed dispatch with every row's window full
maybe_paged_verify_attention = maybe_paged_mixed_attention


def maybe_paged_packed_attention(q, kpool, vpool, ppos, block_tables,
                                 q_pos, meta, *, window, scale,
                                 attn_softcap=None, k_scale=None,
                                 v_scale=None):
    """Token-packed ragged paged attention: q (1, T, Hq, D) is one flat
    stream covering every slot's decode token and prefill-chunk tokens;
    ``meta`` is the (n_work, 4) query-window table from
    ``decode_attention.packed_meta_table``.  q_pos == -1 marks padding
    lanes (zero outputs)."""
    if _MODE == "off":
        return None
    from repro.kernels import decode_attention as DA
    if meta is None or not DA.paged_packed_shape_supported(
            q, kpool, block_tables):
        return None
    if k_scale is not None:
        return DA.paged_packed_attention_q8(
            q, kpool, k_scale, vpool, v_scale, ppos, block_tables, q_pos,
            meta, window=window, scale=scale, attn_softcap=attn_softcap,
            interpret=(_MODE == "interpret"))
    return DA.paged_packed_attention(q, kpool, vpool, ppos, block_tables,
                                     q_pos, meta, window=window,
                                     scale=scale, attn_softcap=attn_softcap,
                                     interpret=(_MODE == "interpret"))


def maybe_quant_matmul(x, q, s):
    """Weight-quantized matmul dispatch: x (..., K) float activations
    against int8 codes q (K, N) + per-output-channel fp32 scales s (N,)
    (see ``precision.quantize_weights``).  Returns fp32 (..., N), or
    None -> caller falls back to ``ref.quant_matmul_ref``."""
    if _MODE == "off":
        return None
    from repro.kernels import quant_matmul as QM
    if not QM.shape_supported(x, q, s):
        return None
    return QM.quant_matmul(x, q, s, interpret=(_MODE == "interpret"))


def maybe_rmsnorm(x, w):
    if _MODE == "off":
        return None
    from repro.kernels import rmsnorm as RN
    if not RN.shape_supported(x):
        return None
    return RN.fused_rmsnorm(x, w, interpret=(_MODE == "interpret"))


def maybe_mlstm_chunked(q, k, v, i_pre, logf, state):
    if _MODE == "off":
        return None
    from repro.kernels import mlstm_chunk as MC
    if not MC.shape_supported(q):
        return None
    return MC.mlstm_chunked_kernel(q, k, v, i_pre, logf, state,
                                   interpret=(_MODE == "interpret"))
