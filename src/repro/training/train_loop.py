"""Training step + loop: cross-entropy LM loss, MoE aux, optional MTP."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.precision import MIXED_TRAIN, Policy
from repro.models import transformer as T
from repro.training import optimizer as OPT

MTP_WEIGHT = 0.3


def cross_entropy(logits, labels, mask):
    """logits (B,S,V) fp32, labels (B,S) int, mask (B,S) -> mean nats."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def _codebook_ce(logits, labels, mask):
    """Audio: logits (B,S,C,V), labels (B,S,C)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = ((logz - gold) * mask[..., None]).sum(-1)
    return nll.sum() / jnp.maximum(mask.sum() * labels.shape[-1], 1.0)


def loss_fn(params, cfg: ModelConfig, batch, policy: Policy,
            remat: bool = True):
    logits, aux = T.forward_train(
        params, cfg, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"), policy=policy, remat=remat)
    labels, mask = batch["labels"], batch["loss_mask"]
    P = cfg.num_prefix_embeds
    if P:
        logits = logits[:, P:]
    if cfg.num_codebooks:
        loss = _codebook_ce(logits, labels, mask)
    else:
        loss = cross_entropy(logits, labels, mask)
    total = loss + aux["moe_aux"]
    if "mtp_logits" in aux:
        mtp_loss = cross_entropy(aux["mtp_logits"][:, :-1],
                                 labels[:, 2:], mask[:, 2:])
        total = total + MTP_WEIGHT * mtp_loss
    return total, {"ce": loss, "moe_aux": aux["moe_aux"]}


def make_train_step(cfg: ModelConfig, opt_cfg: OPT.AdamWConfig,
                    policy: Policy = MIXED_TRAIN, remat: bool = True,
                    grad_accum: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params', state', m).

    grad_accum > 1 (§Perf): the global batch is split into sequentially
    accumulated microbatches (a lax.scan), dividing activation/logit peak
    memory by the accumulation factor.  Gradients accumulate in the
    parameter dtype.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, policy, remat)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, parts), grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def mb(carry, mbatch):
                gsum, lsum = carry
                (loss, parts), g = grads_of(params, mbatch)
                gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                    gsum, g)
                return (gsum, lsum + loss), parts

            gz = jax.tree.map(lambda p: jnp.zeros_like(p), params)
            (gsum, lsum), parts_all = jax.lax.scan(
                mb, (gz, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            parts = jax.tree.map(lambda x: x[-1], parts_all)
        params, opt_state, om = OPT.apply_updates(opt_cfg, params, grads,
                                                  opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, policy: Policy = MIXED_TRAIN):
    def eval_step(params, batch):
        loss, parts = loss_fn(params, cfg, batch, policy, remat=False)
        return {"loss": loss, **parts}
    return eval_step


def train(cfg: ModelConfig, params, batches, *, steps: int,
          opt_cfg: Optional[OPT.AdamWConfig] = None,
          policy: Policy = MIXED_TRAIN, log_every: int = 10,
          callback=None):
    """Single-host training loop (examples / smoke tests)."""
    opt_cfg = opt_cfg or OPT.AdamWConfig(total_steps=steps)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, policy))
    opt_state = OPT.init_state(params)
    history = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in m.items()}
            history.append({"step": i, **m})
            if callback:
                callback(i, m)
    return params, opt_state, history
