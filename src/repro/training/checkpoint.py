"""Checkpointing: pytree <-> flat .npz with path-keyed entries."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, params: Any, opt_state: Any = None,
         meta: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = {f"p:{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        blob.update({f"o:{k}": v for k, v in _flatten(opt_state).items()})
    np.savez(path, **blob)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2, default=str)


def restore(path: str, params_template: Any,
            opt_template: Any = None) -> Tuple[Any, Any]:
    """Restore into the structure of the given templates."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")

    def fill(template, prefix):
        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for pth, leaf in leaves_p:
            key = prefix + "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            arr = data[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            out.append(jnp.asarray(arr, dtype=leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, out)

    params = fill(params_template, "p:")
    opt = fill(opt_template, "o:") if opt_template is not None else None
    return params, opt
