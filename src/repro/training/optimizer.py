"""AdamW + LR schedules in pure JAX (no optax dependency).

Two second-moment modes:

  * full      — standard AdamW (default everywhere)
  * factored  — Adafactor-style: for each >=2-D parameter, the second
    moment is stored as a row statistic (shape[:-1]) and a column
    statistic (shape[:-2] + last), reconstructed as
    ``v_ij ~ r_i * c_j / mean_j'(r)``, and the first moment is dropped.
    This is the §Perf memory fix for >100B-parameter training on a single
    16GB-HBM pod: full AdamW state for 671B params simply does not fit
    (see EXPERIMENTS.md §Perf target B).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    factored: bool = False


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: object            # pytree like params, or None (factored mode)
    nu: object            # pytree like params, or tuple of arrays/dicts


def init_state(params, moment_dtype=jnp.float32,
               factored: bool = False) -> AdamWState:
    """moment_dtype=bf16 is the low-memory mode used for the >100B-param
    dry-runs (noted in EXPERIMENTS.md); fp32 everywhere else."""
    step = jnp.zeros((), jnp.int32)
    if not factored:
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=moment_dtype),
                             params)
        return AdamWState(step=step, mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))
    flat, _ = jax.tree_util.tree_flatten(params)
    nu = tuple(
        {"r": jnp.zeros(p.shape[:-1], jnp.float32),
         "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        if p.ndim >= 2 else jnp.zeros_like(p, dtype=jnp.float32)
        for p in flat)
    return AdamWState(step=step, mu=None, nu=nu)


def factored_nu_pspecs(param_specs, params_struct):
    """PartitionSpecs for the factored nu tuple, derived from param specs
    (drop the dim the statistic reduces over).  Factoring is decided by the
    *parameter's* rank (matching init_state), not the spec length."""
    from jax.sharding import PartitionSpec as P
    flat_s, _ = jax.tree_util.tree_flatten(param_specs)
    flat_p, _ = jax.tree_util.tree_flatten(params_struct)
    out = []
    for spec, p in zip(flat_s, flat_p):
        t = tuple(spec) + (None,) * (p.ndim - len(tuple(spec)))
        if p.ndim >= 2:
            out.append({"r": P(*t[:-1]), "c": P(*(t[:-2] + t[-1:]))})
        else:
            out.append(P(*t))
    return tuple(out)


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(sum(leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def decayed(p, delta):
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    if not cfg.factored:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                          state.nu, grads)

        def upd(p, m, v):
            mhat = m.astype(jnp.float32) / b1c
            vhat = v.astype(jnp.float32) / b2c
            return decayed(p, mhat / (jnp.sqrt(vhat) + cfg.eps))

        new_params = jax.tree.map(upd, params, mu, nu)
        mu = jax.tree.map(lambda a, b: a.astype(b.dtype), mu, state.mu)
        nu = jax.tree.map(lambda a, b: a.astype(b.dtype), nu, state.nu)
        return new_params, AdamWState(step, mu, nu), \
            {"lr": lr, "gnorm": gnorm}

    # ---- factored (Adafactor-style, no first moment) --------------------
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    new_p, new_nu = [], []
    for p, g, v in zip(flat_p, flat_g, state.nu):
        g = g.astype(jnp.float32) * scale
        g2 = g * g
        if isinstance(v, dict):
            r = cfg.b2 * v["r"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
            c = cfg.b2 * v["c"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
            rmean = jnp.mean(r, axis=-1, keepdims=True)
            vhat = (r[..., :, None] * c[..., None, :]
                    / jnp.maximum(rmean[..., None], 1e-30)) / b2c
            new_nu.append({"r": r, "c": c})
        else:
            vfull = cfg.b2 * v + (1 - cfg.b2) * g2
            vhat = vfull / b2c
            new_nu.append(vfull)
        new_p.append(decayed(p, g / (jnp.sqrt(vhat) + cfg.eps)))
    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    return new_params, AdamWState(step, None, tuple(new_nu)), \
        {"lr": lr, "gnorm": gnorm}
