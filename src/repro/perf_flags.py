"""Perf-experiment switches (EXPERIMENTS.md §Perf).

The hillclimb loop needs to lower the *same* model with and without a
candidate optimization, from subprocess-driven dry-runs.  Flags live in the
``REPRO_PERF_OPTS`` env var (comma-separated, ``key`` or ``key=value``) so
they propagate to dry-run subprocesses without touching the config system:

  attn_bf16       compute attention scores/PV from half-precision inputs
                  with fp32 MXU accumulation (no materialized fp32 cast of
                  the KV cache)
  tp_attn_guard   replicate attention weights when head counts don't
                  divide the TP degree (prevents GSPMD full-activation
                  reshards on e.g. 14-head models at TP=16)
  bf16_params     train giant (>100B) archs with bf16 parameter storage
  factored_opt    Adafactor-style factored second moment for giant archs
  grad_accum=N    split the train batch into N sequentially-accumulated
                  microbatches
  coll_bf16       cast fp32 activation tensors to bf16 before cross-chip
                  collectives (halves collective bytes)

Winning flags are promoted to defaults at the end of the perf pass; the
paper-faithful baseline is always recoverable with REPRO_PERF_OPTS="".
"""
from __future__ import annotations

import os
from typing import Dict, Optional

# flags promoted to default after §Perf validation.  attn_bf16 is the
# paper-faithful choice (FasterTransformer computes attention in fp16 with
# fp32 accumulation); REPRO_PERF_OPTS="" still recovers the pre-promotion
# fp32-cast baseline.
_DEFAULTS_ON = ("attn_bf16",)


def _parse() -> Dict[str, str]:
    raw = os.environ.get("REPRO_PERF_OPTS")
    out = {k: "1" for k in _DEFAULTS_ON}
    if raw is None:
        return out
    if raw.strip() == "":
        return {}                     # explicit empty = pure baseline
    out = {}
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
        else:
            out[part] = "1"
    return out


def flag(name: str) -> bool:
    return name in _parse()


def flag_value(name: str, default: Optional[str] = None) -> Optional[str]:
    return _parse().get(name, default)


def active() -> Dict[str, str]:
    return _parse()
