"""Prefix caching end to end (beyond-paper; EXPERIMENTS.md §Perf):
requests that share a system prompt map its KV *pages* zero-copy out of
the radix prefix cache and only prefill their own suffixes — the paper's
"extract relevant content offline" applied across requests.

Two flavours are shown:
  1. automatic: serve a shared-prefix trace twice, cold trie vs warm —
     matching happens per request with no API calls at all;
  2. seeded: ``engine.set_prefix`` prefill-and-pins the system prompt
     up front, so even the very first request skips it.

    PYTHONPATH=src python examples/prefix_serving.py
"""
import copy
import time

import jax
import numpy as np

from repro.configs.registry import get_reduced
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.scheduler import Request
from repro.models import transformer as T


def build_requests(rng, system_prompt, n=8, suffix=8, max_new=8):
    return [Request(uid=i,
                    tokens=system_prompt + list(map(int, rng.integers(
                        4, 400, size=suffix))),
                    max_new_tokens=max_new)
            for i in range(n)]


def serve(eng, reqs, **kw):
    t0 = time.perf_counter()
    done, metrics = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                         **kw)
    return done, metrics, time.perf_counter() - t0


def main():
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    system_prompt = [2] + list(rng.integers(4, 400, size=63))   # 64 tokens
    reqs = build_requests(rng, system_prompt)

    eng = InferenceEngine(cfg, params, policy=FP32, max_len=96, max_batch=4)

    # -- baseline: sharing disabled — every request prefills 72 tokens
    serve(eng, reqs, prefix_cache=False)                        # warm jit
    done_off, m_off, t_off = serve(eng, reqs, prefix_cache=False)

    # -- automatic radix matching (cold trie: the first request in each
    #    slot seeds it, the rest match and skip the system prompt)
    serve(eng, reqs, prefix_cache=True)                         # warm jit
    eng.reset_prefix_cache()
    done_cold, m_cold, t_cold = serve(eng, reqs, prefix_cache=True)

    # -- seeded: set_prefix pins the system prompt before any traffic
    eng.reset_prefix_cache()
    eng.set_prefix(system_prompt, page_size=8)
    serve(eng, reqs, prefix_cache=True)                         # warm jit
    eng.reset_prefix_cache()
    eng.set_prefix(system_prompt, page_size=8)
    done_seed, m_seed, t_seed = serve(eng, reqs, prefix_cache=True)

    for a, b, c in zip(done_off, done_cold, done_seed):
        assert a.result == b.result == c.result, "prefix caching must be exact"

    plen = len(system_prompt) + 8
    print(f"no sharing    : {t_off*1e3:7.1f} ms  "
          f"(prefill {m_off.prefill_tokens} tokens over {len(reqs)} "
          f"requests of {plen})")
    print(f"radix, cold   : {t_cold*1e3:7.1f} ms  "
          f"(prefill {m_cold.prefill_tokens}, matched "
          f"{m_cold.prefix_matched_tokens}, hit-rate "
          f"{m_cold.prefix_hit_rate:.0%})")
    print(f"radix, seeded : {t_seed*1e3:7.1f} ms  "
          f"(prefill {m_seed.prefill_tokens}, matched "
          f"{m_seed.prefix_matched_tokens}, hit-rate "
          f"{m_seed.prefix_hit_rate:.0%}, hits {m_seed.prefix_hits}/"
          f"{len(reqs)})")
    print(f"outputs identical; prefill-token reduction "
          f"{1 - m_seed.prefill_tokens / m_off.prefill_tokens:.0%} — "
          f"shared pages are mapped copy-on-write, never recomputed")


if __name__ == "__main__":
    main()
