"""Prefix caching end to end (beyond-paper; EXPERIMENTS.md §Perf):
precompute a shared system-prompt's KV/state cache once, then serve many
requests that only prefill their suffixes.

    PYTHONPATH=src python examples/prefix_serving.py
"""
import time

import jax
import numpy as np

from repro.configs.registry import get_reduced
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.models import transformer as T


def main():
    cfg = get_reduced("gemma2-2b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=160)
    rng = np.random.default_rng(0)

    system_prompt = [2] + list(rng.integers(4, 400, size=63))   # 64 tokens
    suffixes = rng.integers(4, 400, size=(4, 8)).astype(np.int32)
    lens = np.full(4, 8, np.int32)

    # without prefix caching: full prompts every time
    full = np.concatenate(
        [np.tile(system_prompt, (4, 1)).astype(np.int32), suffixes], axis=1)
    flens = np.full(4, full.shape[1], np.int32)
    eng.generate_batch(full.copy(), flens.copy(), 8)            # warm
    t0 = time.perf_counter()
    g_full = eng.generate_batch(full, flens, 8)
    t_full = time.perf_counter() - t0

    # with prefix caching: the 64-token system prompt is prefilled ONCE
    eng.set_prefix(system_prompt)
    eng.generate_batch(suffixes.copy(), lens.copy(), 8)         # warm
    t0 = time.perf_counter()
    g_pc = eng.generate_batch(suffixes, lens, 8)
    t_pc = time.perf_counter() - t0

    assert (g_full == g_pc).all(), "prefix caching must be exact"
    print(f"full-prompt serve : {t_full*1e3:7.1f} ms "
          f"(prefill {full.shape[1]} tokens/slot)")
    print(f"prefix-cached     : {t_pc*1e3:7.1f} ms "
          f"(prefill {suffixes.shape[1]} tokens/slot)")
    print(f"outputs identical; speedup {t_full/t_pc:.2f}x — the paper's "
          f"'extract relevant content offline' applied to KV state")


if __name__ == "__main__":
    main()
