"""Serve-time int8 weight-only quantization (weights_dtype policy axis).

One `dataclasses.replace(policy, weights_dtype="int8")` turns every
serve-path dense matmul — attention qkv/out, dense FFN, the unembed
head — into int8 codes + per-output-channel fp32 scales at engine
build.  Decode streams ~1/4 of the fp32 weight bytes per step (~1/2 of
bf16); on TPU the dequant is fused into a Pallas matmul kernel, on CPU
an exact jnp fallback computes the same `(x @ q) * s` product.

This demo serves the same trace at full-precision weights and at int8,
then prints the weight-byte footprint and per-request greedy agreement
(recorded, not asserted: weight quantization has no bit-exactness
guarantee — a request whose greedy margin sits below the quantization
noise can flip, though this trace matches exactly).

    PYTHONPATH=src python examples/quantized_weights_serving.py
"""
import copy
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import get_reduced
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.scheduler import Request
from repro.models import transformer as T


def build_requests(rng, n=8, max_new=8):
    return [Request(uid=i,
                    tokens=[2] + list(map(int, rng.integers(
                        4, 400, size=int(rng.integers(6, 16))))),
                    max_new_tokens=max_new)
            for i in range(n)]


def serve(eng, reqs):
    t0 = time.perf_counter()
    done, metrics = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                         max_batched_tokens=32,
                                         prefix_cache=True)
    return done, metrics, time.perf_counter() - t0


def main():
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = build_requests(np.random.default_rng(0))

    legs = {}
    for name, wd in (("fp32", "auto"), ("int8", "int8")):
        pol = dataclasses.replace(FP32, weights_dtype=wd)
        eng = InferenceEngine(cfg, params, policy=pol, max_len=64,
                              max_batch=4)
        serve(eng, reqs)                                    # warm jit
        eng.reset_prefix_cache()
        legs[name] = serve(eng, reqs)

    done_fp, m_fp, t_fp = legs["fp32"]
    done_q8, m_q8, t_q8 = legs["int8"]
    match = sum(a.result == b.result for a, b in zip(done_fp, done_q8))

    dense = m_q8.weight_bytes + m_q8.weight_bytes_saved
    print(f"fp32 weights : {t_fp*1e3:7.1f} ms  "
          f"({m_fp.weight_bytes/1e6:.2f} MB serve-path weights)")
    print(f"int8 weights : {t_q8*1e3:7.1f} ms  "
          f"({m_q8.weight_bytes/1e6:.2f} MB codes+scales, "
          f"{m_q8.weight_bytes/dense:.0%} of dense — "
          f"{m_q8.weight_bytes_saved/1e6:.2f} MB saved)")
    print(f"greedy agreement vs fp32: {match}/{len(reqs)} requests "
          f"(recorded per run; tied gather table stays full precision, "
          f"unembed reads a separate int8 head)")


if __name__ == "__main__":
    main()
