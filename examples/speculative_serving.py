"""Speculative decoding on the continuous serving path.

Serves one trace three ways — plain continuous decoding, draft-verify
with the n-gram prompt-lookup drafter, and draft-verify with a draft
model (here: the model drafting for itself, the degenerate reference
setup whose greedy drafts are always accepted) — and shows that

  * the greedy token streams are bit-identical across all three (the
    rejection sampler is exact-match greedy at temperature 0), and
  * speculation raises tokens-per-forward: each verify forward can emit
    several accepted tokens per slot instead of exactly one.

Run:  PYTHONPATH=src python examples/speculative_serving.py
"""
import copy

import jax
import numpy as np

from repro.configs.registry import get_reduced
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.scheduler import Request
from repro.core.speculative import SpecConfig
from repro.models import transformer as T


def main():
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    # a multi-tenant-ish trace: requests share a system prompt, which
    # also gives the n-gram drafter history to look continuations up in
    shared = [2] + list(map(int, rng.integers(4, 400, size=24)))
    reqs = [Request(uid=i,
                    tokens=shared + list(map(int, rng.integers(
                        4, 400, size=int(rng.integers(2, 8))))),
                    max_new_tokens=16)
            for i in range(8)]

    def serve(spec):
        eng = InferenceEngine(cfg, params, policy=FP32, max_len=96,
                              max_batch=4)
        done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                       spec=spec)
        return done, m

    base, m0 = serve(None)
    ngram, m1 = serve(SpecConfig(k=4, drafter="ngram"))
    draft, m2 = serve(SpecConfig(k=4, drafter="draft_model"))

    for name, done, m in (("continuous", base, m0),
                          ("spec/ngram", ngram, m1),
                          ("spec/draft", draft, m2)):
        ident = all(a.result == b.result for a, b in zip(base, done))
        print(f"{name:12s} tokens/forward={m.tokens_per_forward:5.2f}  "
              f"acceptance={m.acceptance_rate:5.2f}  "
              f"drafted={m.drafted_tokens:4d}  "
              f"outputs==continuous: {ident}")
        assert ident, "speculative greedy serving must be bit-identical"
    assert m2.tokens_per_forward > 1.0
    print("\nK tuning: larger k amortizes more forwards when acceptance "
          "is high (self-draft), but wastes verify width when the "
          "drafter misses (k=4 is a reasonable default).")


if __name__ == "__main__":
    main()
