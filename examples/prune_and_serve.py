"""The paper's P2 pillar end to end: measure corpus coverage, prune the
embedding + position tables, verify output equivalence, serve.

    PYTHONPATH=src python examples/prune_and_serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.core import pruning as PR
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.tokenizer import FastTokenizer
from repro.data.pipeline import synthetic_corpus
from repro.models import transformer as T


def main():
    cfg = get_reduced("unimo-text")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    corpus = synthetic_corpus(800)
    tok = FastTokenizer.train(corpus, cfg.vocab_size)
    freqs = tok.count_frequencies(corpus)

    used = sum(1 for c in freqs.values() if c > 0)
    print(f"vocab {cfg.vocab_size}, used by corpus: {used} "
          f"({100*used/cfg.vocab_size:.1f}%) — the paper's observation")

    p2, cfg2, maps = PR.prune_model(params, cfg, dict(freqs),
                                    coverage=0.999, new_max_len=64)
    emb0 = params["embed"]["tokens"].size + params["embed"]["pos"].size
    emb1 = p2["embed"]["tokens"].size + p2["embed"]["pos"].size
    print(f"embedding params: {emb0:,} -> {emb1:,} "
          f"({emb0/emb1:.1f}x smaller; paper trims 12800-vocab + 512->128)")

    # equivalence check on kept tokens
    toks = jnp.asarray(np.random.default_rng(0).choice(
        maps.keep_ids, size=(2, 12)), jnp.int32)
    lg1, _ = T.forward_train(params, cfg, toks, policy=FP32, remat=False)
    lg2, _ = T.forward_train(p2, cfg2, jnp.asarray(
        PR.remap_tokens(np.asarray(toks), maps)), policy=FP32, remat=False)
    err = float(jnp.max(jnp.abs(lg1[:, :, maps.keep_ids] - lg2)))
    print(f"kept-token logit max |err|: {err:.2e} (exactness invariant)")

    engine = InferenceEngine(cfg2, p2, policy=FP32, max_len=96,
                             prune_maps=maps)
    texts = synthetic_corpus(4, seed=9)
    for t in texts:
        ids = np.asarray([tok.encode(t)], np.int32)
        out = engine.generate_batch(ids, np.array([ids.shape[1]]), 8)
        print(f"  {t[:40]!r} -> {tok.decode(out[0][out[0] >= 0])!r}")


if __name__ == "__main__":
    main()
