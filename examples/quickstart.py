"""Quickstart: build a model, run the paper's optimized inference stack.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.registry import get_reduced
from repro.core.engine import InferenceEngine
from repro.core.pipeline import run_pipelined
from repro.core.precision import BF16
from repro.core.tokenizer import FastTokenizer
from repro.data.pipeline import synthetic_corpus
from repro.models import transformer as T


def main():
    # 1. pick an architecture (any of the ten assigned ids works)
    cfg = get_reduced("qwen3-4b")
    print(f"model: {cfg.name}  layers={cfg.num_layers} d={cfg.d_model}")

    # 2. init params (randomly — no checkpoints ship offline)
    params = T.init_params(jax.random.PRNGKey(0), cfg, BF16)

    # 3. train a tokenizer on a corpus (paper P4: Faster Tokenizer)
    corpus = synthetic_corpus(300)
    tok = FastTokenizer.train(corpus, 500)

    # 4. serve through the paper's stack: KV cache + bf16 + dynamic
    #    batching + staged pipeline
    engine = InferenceEngine(cfg, params, policy=BF16, max_batch=4,
                             max_len=128)
    texts = ["brand value deal", "smart cloud model", "fast search data"]
    results = run_pipelined(texts, tok, engine, max_new_tokens=8)
    for r in results:
        print(f"[{r.uid}] prompt={texts[r.uid]!r} -> {r.token_ids}")

    st = engine.stats
    print(f"prefill {st.prefill_s:.3f}s, decode {st.decode_s:.3f}s, "
          f"{st.generated_tokens} tokens")


if __name__ == "__main__":
    main()
