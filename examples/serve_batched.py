"""End-to-end serving driver (deliverable b): batched requests through the
full optimized stack, reproducing the paper's Table-1 stage structure.

    PYTHONPATH=src python examples/serve_batched.py [--requests 24]

Delegates to ``benchmarks.table1`` so the example and the benchmark can
never drift apart.  Host caveats (single CPU core): the pipeline stage's
overlap gain requires the model stage to run on an accelerator, and bf16
is emulated — see EXPERIMENTS.md §Paper-validation for the analysis.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.table1 import run_table1  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--half", default="bf16", choices=["bf16", "fp16",
                                                       "fp32"])
    args = ap.parse_args()

    print("paper Table-1 stages (scaled UNIMO-text, synthetic workload):")
    rows = run_table1(n_requests=args.requests, half=args.half)
    print(f"  {'stage':28s} {'seconds':>8s} {'req/s':>8s} {'speedup':>8s}")
    for name, sec, sps, speed in rows:
        print(f"  {name:28s} {sec:8.2f} {sps:8.2f} {speed:7.2f}x")
    print(f"\n  cumulative: {rows[-1][3]:.2f}x "
          f"(paper reports 8.96x on GPU at full scale)")


if __name__ == "__main__":
    main()
