"""Train a small LM for a few hundred steps (deliverable b, training kind).

    PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse

import jax

from repro.configs.base import LayerSpec, ModelConfig, uniform_stack
from repro.core.tokenizer import FastTokenizer
from repro.data.pipeline import packed_batches, synthetic_corpus
from repro.models import transformer as T
from repro.training.train_loop import train
from repro.core.precision import FP32
from repro.training.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    # ~qwen3-family shape scaled to the CPU host
    cfg = ModelConfig(
        name="tiny-qwen", family="dense", d_model=256, num_heads=8,
        num_kv_heads=2, head_dim=32, d_ff=768, vocab_size=512,
        stacks=uniform_stack(4, LayerSpec()), qk_norm=True,
        activation="swiglu", norm="rmsnorm")
    corpus = synthetic_corpus(3000)
    tok = FastTokenizer.train(corpus, cfg.vocab_size)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n:,} params, {args.steps} steps")

    batches = packed_batches(tok, corpus, batch_size=8, seq_len=64)
    params, _, hist = train(
        cfg, params, batches, steps=args.steps, policy=FP32,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=30,
                            total_steps=args.steps),
        log_every=25,
        callback=lambda i, m: print(
            f"  step {i:4d}  loss {m['loss']:.4f}  gnorm {m['gnorm']:.2f}"))
    drop = hist[0]["loss"] - hist[-1]["loss"]
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"({drop:.3f} nats learned)")
    assert drop > 0.3, "training failed to learn"


if __name__ == "__main__":
    main()
