"""Property tests: chunked flash (lax.scan) attention == naive reference,
RoPE shift property, masks."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models import layers as L

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


@st.composite
def attn_case(draw):
    B = draw(st.integers(1, 3))
    Hkv = draw(st.sampled_from([1, 2, 4]))
    g = draw(st.sampled_from([1, 2, 4]))
    D = draw(st.sampled_from([8, 16, 32]))
    Sq = draw(st.integers(1, 48))
    Sk = draw(st.integers(1, 80))
    window = draw(st.sampled_from([None, 8, 32]))
    seed = draw(st.integers(0, 2 ** 31))
    return B, Hkv, g, D, Sq, Sk, window, seed


@given(attn_case())
def test_chunked_equals_ref(case):
    B, Hkv, g, D, Sq, Sk, window, seed = case
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hkv * g, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, Hkv, D)), jnp.float32)
    # arbitrary valid/invalid positions
    kp = jnp.asarray(rng.integers(-1, Sk, size=(B, Sk)), jnp.int32)
    qp = jnp.asarray(rng.integers(0, Sk + 4, size=(B, Sq)), jnp.int32)
    ref = L.attention_ref(q, k, v, qp, kp, window=window, scale=D ** -0.5)
    chunk = L.attention_chunked(q, k, v, qp, kp, window=window,
                                scale=D ** -0.5, block=16)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_rope_relative_shift():
    """RoPE inner products depend only on relative positions."""
    rng = np.random.default_rng(0)
    D = 32
    q = jnp.asarray(rng.normal(size=(1, 1, 1, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, 1, D)), jnp.float32)

    def score(pq, pk):
        qr = L.rope(q, jnp.array([[pq]]), 10000.0)
        kr = L.rope(k, jnp.array([[pk]]), 10000.0)
        return float(jnp.sum(qr * kr))

    assert abs(score(5, 3) - score(105, 103)) < 1e-3
    assert abs(score(7, 0) - score(1007, 1000)) < 1e-3


def test_softcap_bounds():
    x = jnp.linspace(-1000, 1000, 101)
    y = L.softcap(x, 50.0)
    assert float(jnp.max(jnp.abs(y))) <= 50.0
    np.testing.assert_allclose(np.asarray(L.softcap(x, None)),
                               np.asarray(x))


@given(st.integers(0, 2 ** 31), st.sampled_from([None, 4, 16]))
def test_position_mask_properties(seed, window):
    rng = np.random.default_rng(seed)
    B, Sq, Sk = 2, 8, 12
    qp = jnp.asarray(rng.integers(0, 20, size=(B, Sq)), jnp.int32)
    kp = jnp.asarray(rng.integers(-1, 20, size=(B, Sk)), jnp.int32)
    m = np.asarray(L.position_mask(qp, kp, window))
    qpn, kpn = np.asarray(qp), np.asarray(kp)
    for b in range(B):
        for i in range(Sq):
            for j in range(Sk):
                expect = kpn[b, j] >= 0 and kpn[b, j] <= qpn[b, i]
                if window is not None:
                    expect = expect and kpn[b, j] > qpn[b, i] - window
                assert m[b, i, j] == expect
