"""Precision policies (paper P1: FP16 inference) + training substrate."""
import os
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_reduced
from repro.core.precision import BF16, FP16, FP32, get_policy
from repro.data.pipeline import packed_batches, synthetic_corpus
from repro.core.tokenizer import FastTokenizer
from repro.models import transformer as T
from repro.training import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training.train_loop import train


def test_policy_casting(key):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(key, cfg)
    p16 = FP16.cast_params(params)
    dt = {str(x.dtype) for x in jax.tree.leaves(p16)}
    assert dt == {"float16"}
    assert get_policy("bf16") is BF16


def test_half_precision_close_to_fp32(key, rng):
    """The paper's claim: FP16 inference preserves quality. Logits must
    stay close and the greedy argmax must agree on a decisive model."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(key, cfg)
    toks = jnp.asarray(rng.integers(4, cfg.vocab_size, size=(2, 12)),
                       jnp.int32)
    lg32, _ = T.forward_train(params, cfg, toks, policy=FP32, remat=False)
    for pol in (FP16, BF16):
        ph = pol.cast_params(params)
        lgh, _ = T.forward_train(ph, cfg, toks, policy=pol, remat=False)
        assert lgh.dtype == jnp.float32            # logits stay fp32
        err = float(jnp.max(jnp.abs(lgh - lg32)))
        scale = float(jnp.max(jnp.abs(lg32))) + 1e-6
        assert err / scale < 0.12, f"{pol}: {err/scale}"
        agree = float(jnp.mean((jnp.argmax(lgh, -1)
                                == jnp.argmax(lg32, -1)).astype(jnp.float32)))
        assert agree > 0.7


def test_loss_decreases(key):
    cfg = get_reduced("unimo-text").replace(vocab_size=256)
    corpus = synthetic_corpus(300, seed=1)
    tok = FastTokenizer.train(corpus, 256)
    params = T.init_params(key, cfg)
    batches = packed_batches(tok, corpus, batch_size=4, seq_len=32)
    _, _, hist = train(cfg, params, batches, steps=30, policy=FP32,
                       log_every=29)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.3


def test_lr_schedule():
    c = OPT.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(OPT.lr_at(c, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] < lrs[1] < lrs[2]
    assert abs(lrs[2] - 1e-3) < 1e-8   # fp32 peak-lr roundoff
    assert lrs[4] >= c.lr * c.min_lr_frac - 1e-9
    assert lrs[3] < lrs[2]


def test_grad_clip(key, rng):
    cfg = get_reduced("unimo-text")
    params = T.init_params(key, cfg)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 100.0, params)
    st = OPT.init_state(params)
    _, _, m = OPT.apply_updates(OPT.AdamWConfig(grad_clip=1.0), params,
                                grads, st)
    assert float(m["gnorm"]) > 1.0   # reported pre-clip norm


def test_checkpoint_roundtrip(key, tmp_path):
    cfg = get_reduced("gemma2-2b")
    params = T.init_params(key, cfg)
    st = OPT.init_state(params)
    path = os.path.join(tmp_path, "ck.npz")
    CKPT.save(path, params, st, meta={"arch": cfg.name})
    p2, st2 = CKPT.restore(path, params, st)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert os.path.exists(path + ".meta.json")
