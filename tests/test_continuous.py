"""Continuous batching + paged KV cache (beyond-paper serving path).

The contract: serve_continuous produces, for every request, exactly the
greedy tokens that a dedicated unpadded single-request run produces —
across attention, sliding-window, MLA and recurrent families — while
admitting/retiring requests mid-flight from a shared page pool.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core import kv_cache as KV
from repro.core.continuous import ContinuousScheduler, PageAllocator
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.sampling import SamplingParams
from repro.core.scheduler import Request
from repro.kernels import ops as KOPS
from repro.models import transformer as T


def _requests(rng, cfg, lens_new):
    return [Request(uid=i,
                    tokens=[2] + list(map(int, rng.integers(
                        4, min(cfg.vocab_size, 400), size=ln))),
                    max_new_tokens=mn)
            for i, (ln, mn) in enumerate(lens_new)]


def _reference(eng, reqs):
    out = {}
    for r in reqs:
        g = eng.generate_batch(np.asarray([r.tokens], np.int32),
                               np.asarray([len(r.tokens)], np.int32),
                               r.max_new_tokens)
        row = g[0]
        out[r.uid] = [int(t) for t in row[row >= 0]]
    return out


# one arch per cache family: dense attn, window+softcap, MLA latent,
# recurrent, hybrid (window ring + SSM + conv)
@pytest.mark.parametrize("arch", ["qwen3-4b", "gemma2-2b",
                                  "deepseek-v3-671b", "xlstm-125m",
                                  "hymba-1.5b"])
def test_continuous_matches_single_request(arch, rng):
    import dataclasses
    cfg = get_reduced(arch)
    if cfg.moe is not None:
        # capacity-based MoE sheds tokens as a function of *batch
        # composition* (a pre-existing property of the dense path too);
        # give it headroom so the parity contract is well-defined.
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(5, 5), (11, 4), (3, 6), (20, 5)])
    ref = _reference(eng, reqs)
    done, metrics = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                         steps_per_sync=3)
    for r in done:
        assert r.result == ref[r.uid], f"{arch} uid {r.uid}"
    assert metrics.admitted == len(reqs)
    assert metrics.retired == len(reqs)
    assert metrics.generated_tokens == sum(len(v) for v in ref.values())


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "xlstm-125m"])
def test_continuous_batched_admission_equal_lengths(arch, rng):
    """Same-length requests are admitted as ONE batched prefill dispatch;
    dense per-slot state (MLA latent / recurrent) must land in each
    request's own slot, not get broadcast from a single view row."""
    import dataclasses
    cfg = get_reduced(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=3)
    reqs = _requests(rng, cfg, [(7, 4), (7, 4), (7, 4)])
    ref = _reference(eng, reqs)
    done, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   steps_per_sync=2)
    for r in done:
        assert r.result == ref[r.uid], f"uid {r.uid}"


def test_continuous_paged_kernel_interpret(rng):
    """The in-model paged Pallas kernel (interpret mode) must not change
    greedy outputs vs the gather + jnp fallback."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=3)
    reqs = _requests(rng, cfg, [(5, 4), (9, 4), (14, 4)])
    ref = _reference(eng, reqs)
    with KOPS.kernel_mode_ctx("interpret"):
        done, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                       steps_per_sync=2)
    for r in done:
        assert r.result == ref[r.uid]


def test_continuous_constrained_pool(rng):
    """A pool too small to hold all requests at once still serves them all
    (admission control queues the overflow until pages free up)."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=3)
    reqs = _requests(rng, cfg, [(5, 4), (9, 4), (3, 4), (14, 4), (7, 4)])
    ref = _reference(eng, reqs)
    done, metrics = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                         num_pages=5, steps_per_sync=2)
    for r in done:
        assert r.result == ref[r.uid]
    assert metrics.admitted == len(reqs)


def test_continuous_budget_edges(rng):
    """max_new_tokens of 0 and 1 retire at admission."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(5, 0), (5, 1), (5, 3)])
    ref = _reference(eng, reqs)
    done, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8)
    assert done[0].result == []
    assert done[1].result == ref[1][:1]
    assert done[2].result == ref[2]


def test_continuous_eos_at_admission(rng, monkeypatch):
    """First sampled token == EOS -> empty result, slot freed cleanly."""
    import repro.core.engine as E
    from repro.core.tokenizer import EOS
    monkeypatch.setattr(
        E, "sample",
        lambda logits, rng_, sp: jnp.full(logits.shape[:-1], EOS, jnp.int32))
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(5, 4), (7, 4)])
    done, metrics = eng.serve_continuous(reqs, page_size=8)
    assert all(r.result == [] for r in done)
    assert metrics.generated_tokens == 0


def test_continuous_overlong_prompt_truncated(rng):
    """A prompt beyond the context is left-truncated with a warning,
    reserving the request's generation budget, and still served."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    toks = [2] + list(map(int, rng.integers(4, 400, size=200)))
    reqs = [Request(uid=0, tokens=list(toks), max_new_tokens=4),
            Request(uid=1, tokens=list(toks)[:8], max_new_tokens=4)]
    with pytest.warns(UserWarning, match="exceeds the maximum"):
        done, _ = eng.serve_continuous(reqs, page_size=8)
    # recent context kept, budget reserved (64 - 4 = 60 tokens of prompt)
    assert done[0].tokens == toks[-60:]
    assert len(done[0].result) == 4
    assert len(done[1].result) == 4


def test_continuous_sampled_path(rng):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2,
                          seed=7)
    reqs = _requests(rng, cfg, [(5, 6), (9, 6), (3, 6)])
    done, _ = eng.serve_continuous(
        reqs, SamplingParams(temperature=1.0, top_k=20), page_size=8)
    for r in done:
        assert r.result is not None and len(r.result) <= 6
        assert all(0 <= t < cfg.vocab_size for t in r.result)


def test_continuous_arrival_trace(rng):
    """Open-loop arrivals: later requests are admitted mid-flight and
    still match their single-request reference."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(5, 5), (9, 5), (3, 5), (12, 5)])
    ref = _reference(eng, reqs)
    done, metrics = eng.serve_continuous(
        copy.deepcopy(reqs), page_size=8,
        arrivals=[0.0, 0.0, 0.05, 0.1])
    for r in done:
        assert r.result == ref[r.uid]
    assert len(metrics.latency_s) == len(reqs)
    assert metrics.percentile_latency(99) >= metrics.percentile_latency(50)


# ---------------------------------------------------------------------------
# Page allocator / scheduler unit tests
# ---------------------------------------------------------------------------


def test_page_allocator_alloc_free_exhaustion():
    al = PageAllocator(4)
    a = al.alloc(3)
    assert a is not None and len(set(a)) == 3
    assert al.alloc(2) is None          # only 1 left -> no partial alloc
    assert al.free_count == 1
    al.free(a)
    assert al.free_count == 4
    with pytest.raises(ValueError):
        al.free(a)                      # double free
    b = al.alloc(4)
    with pytest.raises(ValueError):
        al.free([99])                   # out of range
    with pytest.raises(ValueError):
        al.free([b[0], b[0]])           # duplicate ids in one call
    al.free(b)


def test_scheduler_fcfs_admit_retire():
    sched = ContinuousScheduler(2, PageAllocator(4), page_size=8)
    r1 = Request(uid=1, tokens=[2] * 10, max_new_tokens=6)   # 2 pages
    r2 = Request(uid=2, tokens=[2] * 20, max_new_tokens=4)   # 3 pages
    r3 = Request(uid=3, tokens=[2] * 3, max_new_tokens=4)    # 1 page
    for r in (r1, r2, r3):
        sched.submit(r)
    s1 = sched.try_admit()
    assert s1 is not None and s1[1].request.uid == 1
    # head-of-line r2 needs 3 pages, only 2 free -> r3 must NOT jump it
    assert sched.try_admit() is None
    sched.slots[s1[0]].emitted = [7, 8]
    st = sched.retire(s1[0])
    assert st.request.result == [7, 8]
    s2 = sched.try_admit()
    assert s2 is not None and s2[1].request.uid == 2
    s3 = sched.try_admit()
    assert s3 is not None and s3[1].request.uid == 3
    sched.retire(s2[0])
    sched.retire(s3[0])
    # every page back in the pool after all retirements
    assert sched.allocator.free_count == 4
    with pytest.raises(ValueError):
        sched.allocator.free([0, 0])         # dup ids in one call


def test_paged_write_gather_roundtrip(rng):
    """paged write (prefill + decode) then gather == the dense positions
    and values that were written."""
    P, page, H, D = 6, 8, 2, 16
    pool = {"pk": jnp.zeros((P, page, H, D)),
            "pv": jnp.zeros((P, page, H, D)),
            "ppos": jnp.full((P, page), -1, jnp.int32)}
    bt = jnp.asarray([[0, 3, -1, -1]], jnp.int32)
    S = 11
    k = jnp.asarray(rng.normal(size=(1, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, H, D)), jnp.float32)
    cache_pos = jnp.asarray([[0, 1, 2, 3, 4, 5, 6, 7, 8, -1, -1]], jnp.int32)
    ring = KV.paged_ring_len(None, page, 4)
    pool = KV.paged_write_prefill(pool, {"k": k, "v": v}, cache_pos, bt,
                                  ring_len=ring)
    kk, vv, kp = KV.paged_gather(pool, bt)
    assert kk.shape == (1, 4 * page, H, D)
    np.testing.assert_array_equal(np.asarray(kp[0, :9]), np.arange(9))
    assert (np.asarray(kp[0, 9:]) == -1).all()
    np.testing.assert_allclose(np.asarray(kk[0, :9]), np.asarray(k[0, :9]),
                               rtol=1e-6)
    # decode write at position 9, then at 10
    for t in range(9, 11):
        pool = KV.paged_write_decode(
            pool, {"k": k[:, t:t + 1], "v": v[:, t:t + 1]},
            jnp.asarray([t], jnp.int32), bt,
            jnp.asarray([True]), ring_len=ring)
    kk, vv, kp = KV.paged_gather(pool, bt)
    np.testing.assert_array_equal(np.asarray(kp[0, :11]), np.arange(11))
    np.testing.assert_allclose(np.asarray(vv[0, :11]), np.asarray(v[0]),
                               rtol=1e-6)
    # inactive write goes to the dump page, not the slot's pages
    pool2 = KV.paged_write_decode(
        pool, {"k": k[:, :1] + 99, "v": v[:, :1]},
        jnp.asarray([3], jnp.int32), bt,
        jnp.asarray([False]), ring_len=ring)
    np.testing.assert_allclose(np.asarray(pool2["pk"][0]),
                               np.asarray(pool["pk"][0]), rtol=0)
    assert int(pool2["ppos"][P - 1].max()) == -1


def test_windowed_ring_reuses_pages(rng):
    """A windowed layer cycles within ceil((W+1)/page) logical pages and
    stored positions keep the mask exact past the window."""
    P, page, H, D = 4, 8, 1, 8
    window = 11                          # ring = 2 pages = 16 slots
    ring = KV.paged_ring_len(window, page, 3)
    assert ring == 16
    pool = {"pk": jnp.zeros((P, page, H, D)),
            "pv": jnp.zeros((P, page, H, D)),
            "ppos": jnp.full((P, page), -1, jnp.int32)}
    bt = jnp.asarray([[1, 2, 0]], jnp.int32)
    for t in range(40):
        kv = jnp.full((1, 1, H, D), float(t))
        pool = KV.paged_write_decode(pool, {"k": kv, "v": kv},
                                     jnp.asarray([t], jnp.int32), bt,
                                     None, ring_len=ring)
    # logical page 2 (physical 0) never touched by the ring
    assert int(pool["ppos"][0].max()) == -1
    kk, _, kp = KV.paged_gather(pool, bt)
    live = np.asarray(kp[0])
    # the ring holds exactly the last 16 positions
    assert set(live[live >= 0]) == set(range(24, 40))
