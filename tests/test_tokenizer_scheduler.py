"""Faster-Tokenizer + dynamic batching properties (paper P4)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.scheduler import (DEFAULT_BUCKETS, DynamicBatcher, Request,
                                  pad_batch, pick_bucket)
from repro.core.tokenizer import EOS, PAD, UNK, FastTokenizer

settings.register_profile("tok", deadline=None, max_examples=30)
settings.load_profile("tok")

WORDS = st.text(alphabet="abcdef ", min_size=0, max_size=60)


def _tok():
    corpus = ["abc abcd ab a b c d", "abc abc ffff", "dead beef face"]
    return FastTokenizer.train(corpus, 64)


@given(WORDS)
def test_decode_encode_roundtrip_chars(text):
    """Every encoded id decodes back; text made of known chars roundtrips
    up to whitespace tokenization."""
    tok = _tok()
    ids = tok.encode(text, bos=False)
    out = tok.decode(ids)
    assert UNK not in ids or any(ch not in "abcdef " for ch in text)
    if all(ch in "abcdef " for ch in text):
        assert out == text


def test_longest_match_priority():
    tok = _tok()
    ids = tok.encode("abcd", bos=False)
    assert ids == [tok.token_to_id["abcd"]]
    ids2 = tok.encode("abce", bos=False)
    assert ids2[0] == tok.token_to_id["abc"]


def test_frequency_counting():
    tok = _tok()
    freq = tok.count_frequencies(["abc abc abc", "ffff"])
    abc = tok.token_to_id["abc"]
    assert freq[abc] == 3


@given(st.lists(st.integers(1, 4000), min_size=1, max_size=40),
       st.integers(1, 8))
def test_batcher_covers_all_requests(lengths, max_batch):
    b = DynamicBatcher(max_batch=max_batch)
    for i, ln in enumerate(lengths):
        b.add(Request(uid=i, tokens=list(range(ln))))
    seen = []
    while True:
        batch = b.next_batch()
        if batch is None:
            break
        assert batch.size <= max_batch
        for r in batch.requests:
            # every request fits its batch's padded bucket
            assert r.prompt_len <= batch.padded_len \
                or batch.padded_len == DEFAULT_BUCKETS[-1]
            seen.append(r.uid)
    assert sorted(seen) == list(range(len(lengths)))


@given(st.integers(1, 5000))
def test_bucket_monotone(length):
    b = pick_bucket(length, DEFAULT_BUCKETS)
    assert b in DEFAULT_BUCKETS
    if length <= DEFAULT_BUCKETS[-1]:
        assert b >= length


def test_pad_batch_shapes():
    b = DynamicBatcher(max_batch=4)
    for i, ln in enumerate([3, 17, 30, 9]):
        b.add(Request(uid=i, tokens=list(range(2, 2 + ln))))
    batch = b.next_batch()
    toks, lens = pad_batch(batch)
    assert toks.shape == (batch.size, batch.padded_len)
    for i, r in enumerate(batch.requests):
        assert lens[i] == r.prompt_len
        assert (toks[i, lens[i]:] == PAD).all()
