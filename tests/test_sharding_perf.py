"""Sharding rules + §Perf machinery (perf flags, factored opt, grad accum,
attn_bf16 equivalence)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.registry import get_config, get_reduced
from repro.core.precision import FP32
from repro.models import transformer as T
from repro.models import layers as L
from repro.sharding import partition as SH
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step
from repro import perf_flags


def _fake_mesh():
    """Abstract 16x16 mesh for spec computation (no devices needed)."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((16, 16), ("data", "model"))
    except TypeError:
        # older AbstractMesh signature: one tuple of (name, size) pairs
        return AbstractMesh((("data", 16), ("model", 16)))


def test_param_pspecs_shapes():
    cfg = get_reduced("qwen3-moe-235b-a22b")
    struct = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    specs = SH.param_pspecs(struct, cfg, fsdp=False)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_name = {}
    for path, spec in flat:
        name = str(getattr(path[-1], "key", path[-1]))
        by_name.setdefault(name, spec)
    # MoE expert weights shard experts over `model` (after leading repeat)
    assert tuple(by_name["wi"])[:2] == (None, "model")
    # embeddings shard vocab over model
    assert tuple(by_name["tokens"])[0] == "model"
    # norms replicated
    assert by_name["w"] == P()


def test_sanitize_drops_nondivisible():
    mesh = _fake_mesh()
    spec = SH.sanitize_spec(P("model", None), (32001, 16), mesh)
    assert tuple(spec) == (None, None)
    spec2 = SH.sanitize_spec(P("model", "data"), (32000, 160), mesh)
    assert tuple(spec2) == ("model", "data")


def test_perf_flags_parse(monkeypatch):
    monkeypatch.setenv("REPRO_PERF_OPTS", "attn_bf16,grad_accum=4")
    assert perf_flags.flag("attn_bf16")
    assert perf_flags.flag_value("grad_accum") == "4"
    assert not perf_flags.flag("tp_attn_guard")
    monkeypatch.setenv("REPRO_PERF_OPTS", "")
    assert not perf_flags.flag("attn_bf16")


def test_tp_attn_guard_replicates(monkeypatch):
    monkeypatch.setenv("REPRO_PERF_OPTS", "tp_attn_guard")
    cfg = get_config("internvl2-1b")          # 14 heads: 14 % 16 != 0
    struct = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0),
                              get_reduced("internvl2-1b")))
    # use the full cfg's head count with the reduced struct for the rule
    specs = SH.param_pspecs(struct, cfg, fsdp=False, mesh=_fake_mesh())
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    for path, spec in flat:
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if "attn" in names and names[-1] in ("wq", "wo"):
            assert spec == P(*(None,) * len(tuple(spec))) or spec == P()


def test_attn_bf16_equivalence(monkeypatch, rng):
    """attn_bf16 must be a pure layout/precision change: fp32 inputs give
    bit-identical results; bf16 inputs stay within bf16 tolerance."""
    B, S, H, D = 1, 32, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    monkeypatch.setenv("REPRO_PERF_OPTS", "")
    base = L.attention_ref(q, q, q, pos, pos, window=None, scale=0.25)
    monkeypatch.setenv("REPRO_PERF_OPTS", "attn_bf16")
    opt = L.attention_ref(q, q, q, pos, pos, window=None, scale=0.25)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt),
                               rtol=1e-6, atol=1e-6)
    qb = q.astype(jnp.bfloat16)
    optb = L.attention_ref(qb, qb, qb, pos, pos, window=None, scale=0.25)
    np.testing.assert_allclose(np.asarray(optb, np.float32),
                               np.asarray(base), rtol=3e-2, atol=3e-2)


def test_factored_optimizer_trains(key):
    """Factored mode must reduce loss comparably on a small problem."""
    from repro.core.tokenizer import FastTokenizer
    from repro.data.pipeline import packed_batches, synthetic_corpus
    cfg = get_reduced("unimo-text").replace(vocab_size=256)
    corpus = synthetic_corpus(200, seed=2)
    tok = FastTokenizer.train(corpus, 256)
    params = T.init_params(key, cfg)
    batches = packed_batches(tok, corpus, batch_size=4, seq_len=32)
    oc = OPT.AdamWConfig(lr=1e-3, warmup_steps=3, total_steps=40,
                         factored=True)
    step = jax.jit(make_train_step(cfg, oc, policy=FP32))
    st = OPT.init_state(params, factored=True)
    losses = []
    for i in range(40):
        b = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, st, m = step(params, st, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_factored_state_memory():
    """The point of factoring: second-moment bytes collapse for matrices."""
    p = {"w": jnp.zeros((512, 512))}
    full = OPT.init_state(p)
    fact = OPT.init_state(p, factored=True)
    full_b = sum(x.size * 4 for x in jax.tree.leaves(full.nu))
    fact_b = sum(x.size * 4 for x in jax.tree.leaves(fact.nu))
    assert fact_b < full_b / 100


def test_factored_nu_pspecs():
    specs = {"w": P(None, "model", None), "r3": P()}
    structs = {"w": jax.ShapeDtypeStruct((4, 16, 8), jnp.float32),
               "r3": jax.ShapeDtypeStruct((2, 3, 5), jnp.float32)}
    out = OPT.factored_nu_pspecs(specs, structs)
    # dict order: "r3" flattens before "w"
    assert tuple(out[1]["r"]) == (None, "model")
    assert tuple(out[1]["c"]) == (None, None)
    assert tuple(out[0]["r"]) == (None, None)   # replicated 3D param


def test_grad_accum_matches_single(key, rng):
    cfg = get_reduced("gemma2-2b")
    params = T.init_params(key, cfg)
    toks = jnp.asarray(rng.integers(4, cfg.vocab_size, size=(4, 16)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones((4, 16), jnp.float32)}
    oc = OPT.AdamWConfig(warmup_steps=1, total_steps=10)
    p1, _, m1 = jax.jit(make_train_step(cfg, oc, policy=FP32))(
        params, OPT.init_state(params), batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, oc, policy=FP32,
                                        grad_accum=2))(
        params, OPT.init_state(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 1e-4
