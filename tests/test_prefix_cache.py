"""Radix prefix cache: cross-request KV page sharing (PR-2 tentpole).

Contracts under test:
  * allocator refcounts never go negative; no leaked pages after a full
    serve (alloc == free + trie-resident);
  * a COW write never mutates a page with refcount > 1 (the writer gets
    a fresh copy of the partial tail page);
  * trie match/insert/evict semantics (LRU, pinning, partial-node
    extension) against a hand-computed oracle;
  * serve_continuous with sharing enabled produces bit-identical sampled
    outputs vs sharing disabled AND vs per-request dense references,
    while measurably skipping prefill work;
  * opted-out layer families (sliding-window, MLA, recurrent, hybrid)
    serve exactly with sharing silently disabled.
"""
import copy

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    # when hypothesis is installed (CI installs it), the invariant
    # harness below also runs as a generative property test
    from hypothesis import given, settings, strategies as st
    settings.register_profile("prefix", deadline=None, max_examples=20)
    settings.load_profile("prefix")
    HAVE_HYPOTHESIS = True
except ImportError:                    # seeded fallback still runs
    HAVE_HYPOTHESIS = False

from repro.configs.registry import get_reduced
from repro.core import kv_cache as KV
from repro.core.continuous import ContinuousScheduler, PageAllocator
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.prefix_cache import RadixPrefixCache, shareable
from repro.core.scheduler import Request
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# Allocator refcounts
# ---------------------------------------------------------------------------


def test_allocator_refcount_lifecycle():
    al = PageAllocator(4)
    pages = al.alloc(2)
    assert al.refcount(pages[0]) == 1
    al.incref(pages[0])
    assert al.refcount(pages[0]) == 2
    al.decref(pages[0])
    assert al.free_count == 2               # still held once
    al.decref(pages[0])
    assert al.free_count == 3               # now back in the pool
    with pytest.raises(ValueError):
        al.decref(pages[0])                 # would go negative
    with pytest.raises(ValueError):
        al.incref(pages[0])                 # incref of a free page
    al.decref(pages[1])
    al.check()
    assert al.free_count == 4 and al.allocated_count == 0


def test_allocator_check_detects_leak():
    al = PageAllocator(3)
    al.alloc(1)
    al.check()                              # 1 resident + 2 free = 3: fine
    al._free.append(99)                     # corrupt on purpose
    with pytest.raises(AssertionError):
        al.check()


# ---------------------------------------------------------------------------
# Radix trie
# ---------------------------------------------------------------------------


def _trie(num_pages=16, ps=4):
    al = PageAllocator(num_pages)
    return RadixPrefixCache(al, ps), al


def test_trie_match_insert_basic():
    trie, al = _trie()
    toks = [1, 2, 3, 4, 5, 6, 7, 8, 9, 10]          # 2.5 pages at ps=4
    pages = al.alloc(3)
    kept = trie.insert(toks, pages, len(toks))
    assert kept == 3
    for p in pages:
        al.decref(p)                                 # trie now sole owner
    assert sorted(trie.resident_pages) == sorted(pages)

    # full match: 2 full pages + partial tail (10 tokens)
    m, mp = trie.match(toks)
    assert m == 10 and mp == pages
    # prefix-of-cached match stops inside the second page
    m, mp = trie.match([1, 2, 3, 4, 5, 6])
    assert m == 6 and mp == pages[:2]
    # divergence after one page
    m, mp = trie.match([1, 2, 3, 4, 99, 98])
    assert m == 4 and mp == pages[:1]
    # no match at all
    m, mp = trie.match([7, 7, 7])
    assert (m, mp) == (0, [])


def test_trie_divergent_siblings_coexist():
    trie, al = _trie()
    a = al.alloc(2)
    b = al.alloc(2)
    trie.insert([1, 2, 3, 4, 5, 5, 5, 5], a, 8)
    trie.insert([1, 2, 3, 4, 6, 6, 6, 6], b, 8)
    # first page deduped (a[0] kept), second spans diverge into siblings
    assert trie.num_nodes == 3
    m, mp = trie.match([1, 2, 3, 4, 6, 6, 6, 6])
    assert m == 8 and mp == [a[0], b[1]]
    for p in a + b:
        al.decref(p)
    assert al.allocated_count == 3                   # b[0] was never kept


def test_trie_partial_node_extension_swaps_page():
    trie, al = _trie()
    short = al.alloc(1)
    trie.insert([1, 2], short, 2)                    # partial tail node
    for p in short:
        al.decref(p)
    longer = al.alloc(1)
    trie.insert([1, 2, 3, 4], longer, 4)             # extends in place
    for p in longer:
        al.decref(p)
    assert trie.num_nodes == 1
    assert trie.resident_pages == [longer[0]]        # page swapped
    assert al.refcount(short[0]) == 0                # old page released
    m, mp = trie.match([1, 2, 3, 4, 9])
    assert m == 4 and mp == [longer[0]]


def test_trie_lru_eviction_and_pinning():
    trie, al = _trie(num_pages=4)
    a = al.alloc(1)
    b = al.alloc(1)
    c = al.alloc(1)
    trie.insert([1, 1, 1, 1], a, 4, pin=True)
    trie.insert([2, 2, 2, 2], b, 4)
    trie.insert([3, 3, 3, 3], c, 4)
    for p in a + b + c:
        al.decref(p)
    trie.match([3, 3, 3, 3])                         # c most recently used
    assert trie.evict(1) == 1                        # LRU unpinned: b
    assert sorted(trie.resident_pages) == sorted(a + c)
    assert trie.evict(5) == 1                        # c evictable, a pinned
    assert trie.resident_pages == a
    trie.unpin_all()
    assert trie.evict(1) == 1
    assert trie.num_nodes == 0
    al.check()
    assert al.free_count == 4


def test_trie_never_evicts_actively_referenced():
    trie, al = _trie(num_pages=4)
    a = al.alloc(1)
    trie.insert([1, 1, 1, 1], a, 4)                  # refcount 2: us + trie
    assert trie.evict(1) == 0                        # we still hold it
    al.decref(a[0])
    assert trie.evict(1) == 1


def test_trie_evict_leaf_before_parent():
    trie, al = _trie(num_pages=4)
    pages = al.alloc(2)
    trie.insert([1, 2, 3, 4, 5, 6, 7, 8], pages, 8)
    for p in pages:
        al.decref(p)
    trie.evict(2)
    al.check()
    assert trie.num_nodes == 0 and al.free_count == 4


# ---------------------------------------------------------------------------
# COW page copy (device op)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantized", [False, True])
def test_copy_pages_keeps_prefix_masks_tail(rng, quantized):
    P, page, H, D = 4, 4, 2, 8
    pool = {"pk": jnp.asarray(rng.normal(size=(P, page, H, D)), jnp.float32),
            "pv": jnp.asarray(rng.normal(size=(P, page, H, D)), jnp.float32),
            "ppos": jnp.asarray([[4, 5, 6, 7], [-1] * 4, [-1] * 4,
                                 [-1] * 4], jnp.int32)}
    if quantized:
        # int8 pool layout: codes + per-entry scale pools travel together
        for kk in ("pk", "pv"):
            q, s = KV.quantize_kv(pool[kk])
            pool[kk] = q
            pool[kk + "_scale"] = s
    out = KV.copy_pages(pool, jnp.asarray([0]), jnp.asarray([2]),
                        jnp.asarray([6]))
    # entries at positions 4,5 kept; 6,7 beyond the match masked
    np.testing.assert_array_equal(np.asarray(out["ppos"][2]),
                                  [4, 5, -1, -1])
    data_keys = [k for k in KV.PAGED_DATA_KEYS if k in pool]
    for kk in data_keys:
        np.testing.assert_allclose(np.asarray(out[kk][2]),
                                   np.asarray(pool[kk][0]))
    # the source page is bit-untouched (copy, not move)
    np.testing.assert_array_equal(np.asarray(out["ppos"][0]),
                                  np.asarray(pool["ppos"][0]))
    for kk in data_keys:
        np.testing.assert_allclose(np.asarray(out[kk][0]),
                                   np.asarray(pool[kk][0]))


def test_copy_pages_dump_row_noop():
    P, page = 3, 4
    pool = {"pk": jnp.zeros((P, page, 1, 2)), "pv": jnp.zeros((P, page, 1, 2)),
            "ppos": jnp.full((P, page), -1, jnp.int32)}
    out = KV.copy_pages(pool, jnp.asarray([P - 1]), jnp.asarray([P - 1]),
                        jnp.asarray([0]))
    assert int(out["ppos"][P - 1].max()) == -1


# ---------------------------------------------------------------------------
# Scheduler + trie: pool invariants under random traffic (host-only)
# ---------------------------------------------------------------------------


def _pool_invariant_trace(trace, num_pages):
    """Drive admission/retire bookkeeping (no device work) with random
    shared-prefix traffic: refcounts stay positive, COW targets are
    always private, and after the last retire every allocated page is
    exactly the trie's residency (alloc == free + resident)."""
    ps = 4
    al = PageAllocator(num_pages)
    trie = RadixPrefixCache(al, ps)
    sched = ContinuousScheduler(2, al, ps, max_pages_per_slot=16,
                                prefix_cache=trie)
    prefixes = {g: [100 + g] * (3 + 2 * g) for g in range(4)}
    for uid, (g, extra, mn) in enumerate(trace):
        toks = prefixes[g] + [uid % 7 + 1] * extra
        sched.submit(Request(uid=uid, tokens=toks, max_new_tokens=mn))
    while sched.has_work():
        progressed = False
        while True:
            adm = sched.try_admit()
            if adm is None:
                break
            progressed = True
            _, stt = adm
            # COW invariant: every page the admission prefill writes
            # (the fresh ones) is private to this request
            for p in stt.fresh_pages:
                assert al.refcount(p) == 1
            if stt.cow_src >= 0:
                assert al.refcount(stt.cow_src) >= 2   # pinned for copy
            sched.release_cow_source(stt)
            plen = stt.request.prompt_len
            sched.insert_prefix(stt, (plen // ps) * ps)
        # emulate decode-to-completion for one occupied slot
        if sched.slots:
            slot = next(iter(sched.slots))
            stt = sched.slots[slot]
            budget = min(stt.request.max_new_tokens, 3)
            stt.emitted = [5] * budget
            sched.retire(slot)
        elif not progressed:
            # head can never fit this pool even after eviction: drop it
            sched.waiting.pop(0)
    al.check()
    resident = trie.resident_pages
    assert len(resident) == len(set(resident))
    assert al.allocated_count == len(resident)
    assert all(al.refcount(p) == 1 for p in resident)


def test_pool_invariants_seeded_traffic():
    """Deterministic sweep of the invariant harness (always runs; the
    hypothesis variant below widens the search when available)."""
    for seed in range(40):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 24))
        trace = [(int(rng.integers(0, 4)), int(rng.integers(1, 30)),
                  int(rng.integers(1, 12))) for _ in range(n)]
        _pool_invariant_trace(trace, int(rng.integers(6, 40)))


if HAVE_HYPOTHESIS:
    @given(st.lists(st.tuples(st.integers(0, 3),      # prefix group
                              st.integers(1, 30),     # extra suffix tokens
                              st.integers(1, 12)),    # max_new
                    min_size=1, max_size=24),
           st.integers(6, 40))
    def test_pool_invariants_random_traffic(trace, num_pages):
        _pool_invariant_trace(trace, num_pages)


# ---------------------------------------------------------------------------
# Engine-level: exactness + savings
# ---------------------------------------------------------------------------


def _requests(rng, cfg, shapes, prefix=None):
    out = []
    for i, (ln, mn) in enumerate(shapes):
        body = list(map(int, rng.integers(4, min(cfg.vocab_size, 400),
                                          size=ln)))
        out.append(Request(uid=i, tokens=([2] + (prefix or []) + body),
                           max_new_tokens=mn))
    return out


def _reference(eng, reqs):
    out = {}
    for r in reqs:
        g = eng.generate_batch(np.asarray([r.tokens], np.int32),
                               np.asarray([len(r.tokens)], np.int32),
                               r.max_new_tokens)
        row = g[0]
        out[r.uid] = [int(t) for t in row[row >= 0]]
    return out


def test_shareable_gate():
    assert shareable(get_reduced("qwen3-4b"), 64) is None
    assert shareable(get_reduced("unimo-text"), 64) is None
    assert shareable(get_reduced("gemma2-2b"), 64) is not None   # window
    assert shareable(get_reduced("deepseek-v3-671b"), 64) is not None  # MLA
    assert shareable(get_reduced("xlstm-125m"), 64) is not None  # recurrent
    assert shareable(get_reduced("hymba-1.5b"), 64) is not None  # hybrid


def test_prefix_sharing_exact_and_saves_prefill(rng):
    """Shared-prefix trace: results must be bit-identical to both the
    dense per-request reference and the sharing-off run, while the
    prefill token count provably drops."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prefix = list(map(int, rng.integers(4, 400, size=21)))
    shapes = [(5, 5), (3, 4), (7, 5), (4, 4), (6, 5)]

    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, shapes, prefix=prefix)
    ref = _reference(eng, reqs)

    eng_off = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                              max_batch=2)
    off, m_off = eng_off.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                          steps_per_sync=3,
                                          prefix_cache=False)
    eng_on = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                             max_batch=2)
    on, m_on = eng_on.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                       steps_per_sync=3, prefix_cache=True)
    for a, b in zip(off, on):
        assert a.result == ref[a.uid]
        assert b.result == ref[b.uid]
    assert m_on.prefix_matched_tokens > 0
    assert m_on.pages_shared > 0
    assert m_on.prefix_hits >= len(reqs) - 2     # first-in-slot pair misses
    assert m_off.prefix_matched_tokens == 0
    # every prompt token is either computed or served from the cache
    total_prompt = sum(r.prompt_len for r in reqs)
    assert m_on.prefill_tokens + m_on.prefix_matched_tokens == total_prompt
    assert m_on.prefill_tokens < m_off.prefill_tokens
    assert 0.0 < m_on.prefix_hit_rate < 1.0
    # per-request observability
    assert sum(r.prefix_tokens_matched for r in on) \
        == m_on.prefix_matched_tokens


def test_identical_prompt_resubmission_hits_cache(rng):
    """The same prompt served twice: the second run matches everything
    but the final token and emits identical output."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(14, 5)])
    ref = _reference(eng, reqs)
    r1, m1 = eng.serve_continuous(copy.deepcopy(reqs), page_size=8)
    r2, m2 = eng.serve_continuous(copy.deepcopy(reqs), page_size=8)
    assert r1[0].result == ref[0] and r2[0].result == ref[0]
    # second pass: everything except the last prompt token may be served
    # from cache (the cache also holds the generated continuation)
    assert m2.prefix_matched_tokens == r2[0].prompt_len - 1
    assert m2.prefill_tokens == 1
    assert m1.prefix_matched_tokens == 0


@pytest.mark.parametrize("arch", ["gemma2-2b", "deepseek-v3-671b",
                                  "xlstm-125m", "hymba-1.5b"])
def test_optout_families_serve_exactly(arch, rng):
    """Window/MLA/recurrent/hybrid layers opt out of sharing; forcing
    prefix_cache=True must warn, disable itself, and still serve every
    request bit-exactly."""
    import dataclasses
    cfg = get_reduced(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(5, 4), (5, 4), (9, 4)],
                     prefix=[7, 8, 9, 10, 11, 12, 13, 14])
    ref = _reference(eng, reqs)
    with pytest.warns(UserWarning, match="disabled"):
        done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                       prefix_cache=True)
    for r in done:
        assert r.result == ref[r.uid], f"{arch} uid {r.uid}"
    assert m.prefix_matched_tokens == 0 and m.pages_shared == 0


def test_set_prefix_seeds_first_wave(rng):
    """engine.set_prefix on the paged path: requests in the very first
    admission wave already skip the seeded prefix."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sys_prompt = [2] + list(map(int, rng.integers(4, 400, size=23)))
    shapes = [(4, 5), (6, 5), (3, 4)]

    eng_ref = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                              max_batch=2)
    reqs = _requests(rng, cfg, shapes)
    for r in reqs:                       # prepend the system prompt
        r.tokens = sys_prompt + r.tokens
    ref = _reference(eng_ref, reqs)

    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    eng.set_prefix(sys_prompt, page_size=8)
    done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8)
    for r in done:
        assert r.result == ref[r.uid]
    assert m.prefix_hits == len(reqs)            # every admission hit
    assert m.prefix_matched_tokens >= len(reqs) * (len(sys_prompt) // 8) * 8
    eng.clear_prefix()                           # unpins; still correct
    done2, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8)
    for r in done2:
        assert r.result == ref[r.uid]


def test_set_prefix_optout_warns_noop():
    cfg = get_reduced("xlstm-125m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    with pytest.warns(UserWarning, match="sharing disabled"):
        eng.set_prefix([2, 3, 4, 5])
    assert eng._paged_ctx is None


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v3-671b"])
def test_dense_resume_prefill_matches_full(arch, rng):
    """Model-level contract kept from the dense prefix era: a prefill
    resumed from a pre-filled cache (``start > 0``, attend-cache) equals
    one uninterrupted prefill — incl. the MLA latent path."""
    cfg = get_reduced(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    B, S, cut = 2, 12, 5
    toks = jnp.asarray(rng.integers(4, min(cfg.vocab_size, 400),
                                    size=(B, S)), jnp.int32)
    c_full = T.init_cache(cfg, B, 32, jnp.float32)
    lg_full, _ = T.forward_prefill(params, cfg, toks,
                                   jnp.full((B,), S, jnp.int32), c_full,
                                   policy=FP32)
    c = T.init_cache(cfg, B, 32, jnp.float32)
    _, c = T.forward_prefill(params, cfg, toks[:, :cut],
                             jnp.full((B,), cut, jnp.int32), c, policy=FP32)
    lg2, _ = T.forward_prefill(params, cfg, toks[:, cut:],
                               jnp.full((B,), S - cut, jnp.int32), c,
                               policy=FP32, start=cut)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lg_full[:, cut:]),
                               rtol=3e-4, atol=3e-4)


def test_eviction_under_pool_pressure_stays_exact(rng):
    """A pool too small to cache every distinct prefix forces LRU
    eviction mid-run; serving stays exact and the books balance."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    pfx = [list(map(int, rng.integers(4, 400, size=17))) for _ in range(3)]
    reqs = []
    for i in range(9):
        body = list(map(int, rng.integers(4, 400, size=3 + i % 3)))
        reqs.append(Request(uid=i, tokens=[2] + pfx[i % 3] + body,
                            max_new_tokens=4))
    ref = _reference(eng, reqs)
    eng2 = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    # 14 pages of 8: two slots need up to 2*ceil((21+4)/8)=8 live pages,
    # while 3 distinct prefixes want 3*3=9 cached -> pressure
    done, m = eng2.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                    num_pages=14)
    for r in done:
        assert r.result == ref[r.uid]
    assert m.prefix_matched_tokens > 0
    ctx = eng2._paged_ctx
    ctx["alloc"].check()
    assert ctx["alloc"].allocated_count == len(ctx["trie"].resident_pages)
