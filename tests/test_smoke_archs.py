"""Required per-arch smoke tests: reduced variant, one forward + one train
step on CPU, asserting output shapes and the absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, get_reduced
from repro.core.precision import FP32
from repro.models import transformer as T
from repro.training import optimizer as OPT
from repro.training.train_loop import make_train_step

B, S = 2, 16


def _batch(cfg, key):
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.num_prefix_embeds:
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            key, (B, cfg.num_prefix_embeds, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED + ["unimo-text"])
def test_smoke_forward_and_train_step(arch, key):
    cfg = get_reduced(arch)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)

    logits, aux = T.forward_train(params, cfg, batch["tokens"],
                                  prefix_embeds=batch.get("prefix_embeds"),
                                  policy=FP32, remat=False)
    S_tot = S + cfg.num_prefix_embeds
    if cfg.num_codebooks:
        assert logits.shape == (B, S_tot, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S_tot, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), "NaN in forward logits"

    step = jax.jit(make_train_step(cfg, OPT.AdamWConfig(warmup_steps=1,
                                                        total_steps=10),
                                   policy=FP32, remat=True))
    opt_state = OPT.init_state(params)
    params2, _, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"])), metrics
    assert not bool(jnp.isnan(metrics["gnorm"])), "NaN gradients"
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     params, params2))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_serve_roundtrip(arch, key):
    """Prefill then two decode steps: shapes + finite outputs + the
    prefill logits match the train forward exactly."""
    cfg = get_reduced(arch)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    pre = batch.get("prefix_embeds")
    S_tot = S + cfg.num_prefix_embeds

    full, _ = T.forward_train(params, cfg, batch["tokens"],
                              prefix_embeds=pre, policy=FP32, remat=False)
    cache = T.init_cache(cfg, B, 64, jnp.float32)
    lengths = jnp.full((B,), S_tot, jnp.int32)
    lg, cache = T.forward_prefill(params, cfg, batch["tokens"], lengths,
                                  cache, prefix_embeds=pre, policy=FP32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full),
                               rtol=2e-4, atol=2e-4)
    tok1 = (batch["tokens"][:, :1])
    for i in range(2):
        lg1, cache = T.forward_decode(params, cfg, tok1, cache,
                                      lengths + i, policy=FP32)
        assert not bool(jnp.isnan(lg1).any())
        assert lg1.shape[0] == B and lg1.shape[1] == 1
