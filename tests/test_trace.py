"""Serve-loop tracing: determinism, schema, zero-perturbation, exporters.

Contracts under test:

  * a ``ServeTracer`` driven by a fake monotonic clock produces
    byte-identical JSONL across two fresh serve runs — every serve-loop
    timestamp flows through the injected clock, and the exporter writes
    canonical (sorted-key, fixed-separator) JSON;
  * every event the engine/scheduler/prefix-cache/host-tier emits
    validates against ``EVENT_SCHEMAS``, and ``validate_event`` rejects
    unknown kinds, missing/extra fields and type mismatches;
  * tracing is observation only: greedy outputs are bit-identical with
    the tracer on vs. off across plain, shared-prefix, int8,
    speculative and preemption/resume serving;
  * trace-derived host/device totals reconcile with ``ServeMetrics``:
    device span time matches ``device_s`` exactly (same timer reads),
    iteration ``host_s`` is bounded by the metrics' host share;
  * the Perfetto exporter emits structurally sound Chrome trace-event
    JSON (balanced B/E per track, counter samples, named threads);
  * ``ServeMetrics.percentile`` matches numpy on non-empty input and is
    zero on empty; ``to_dict`` carries every derived property;
  * ``bench_diff`` passes a baseline against itself, fails on
    regressions and invariant breaks, and skips baseline-relative
    checks on config mismatch.
"""
import copy
import io
import json

import jax
import numpy as np
import pytest

from benchmarks import bench_diff
from repro.configs.registry import get_reduced
from repro.core.continuous import ServeMetrics
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.scheduler import Request
from repro.core import trace as TR
from repro.core.trace import (EVENT_SCHEMAS, ServeTracer, to_perfetto_dict,
                              validate_event, validate_events)
from repro.models import transformer as T


class FakeClock:
    """Deterministic monotonic clock: advances a fixed step per read."""

    def __init__(self, step=0.001):
        self.t = 0.0
        self.step = step

    def __call__(self):
        self.t += self.step
        return self.t


def _requests(rng, cfg, lens_new, prefix=None):
    prefix = prefix or []
    return [Request(uid=i,
                    tokens=[2] + prefix + list(map(int, rng.integers(
                        4, min(cfg.vocab_size, 400), size=ln))),
                    max_new_tokens=mn)
            for i, (ln, mn) in enumerate(lens_new)]


def _serve(eng, reqs, **kw):
    done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   max_batched_tokens=16,
                                   chunked_prefill=True, **kw)
    return {r.uid: r.result for r in done}, m


def _engine(cfg, params, policy=FP32):
    return InferenceEngine(cfg, params, policy=policy, max_len=64,
                           max_batch=3)


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Determinism: fake clock -> byte-identical JSONL
# ---------------------------------------------------------------------------


def _traced_run(cfg, params, reqs, **kw):
    tr = ServeTracer(clock=FakeClock())
    done, m = _serve(_engine(cfg, params), reqs, trace=tr, **kw)
    return tr, done, m


def test_fake_clock_jsonl_byte_identical(rng, model):
    cfg, params = model
    reqs = _requests(rng, cfg, [(14, 4), (22, 4), (9, 3)])
    bufs = []
    for _ in range(2):
        tr, _, _ = _traced_run(cfg, params, reqs)
        buf = io.StringIO()
        tr.to_jsonl(buf)
        bufs.append(buf.getvalue())
    assert bufs[0] == bufs[1]
    lines = bufs[0].splitlines()
    assert json.loads(lines[0])["kind"] == "trace_header"
    kinds = {json.loads(l)["kind"] for l in lines[1:]}
    assert {"enqueue", "admit", "prefill_chunk", "first_token", "span",
            "iteration", "retire"} <= kinds


def test_timestamps_serve_relative_and_monotone_origin(rng, model):
    cfg, params = model
    reqs = _requests(rng, cfg, [(11, 3)])
    tr, _, _ = _traced_run(cfg, params, reqs)
    ts = [e["t"] for e in tr.events]
    assert ts and min(ts) >= 0.0
    # iteration records carry a strictly increasing index from 0
    its = [e["iter"] for e in tr.iter_events("iteration")]
    assert its == list(range(len(its)))


# ---------------------------------------------------------------------------
# Schema: every emitted event validates; validator rejects bad events
# ---------------------------------------------------------------------------


def test_emitted_events_schema_valid_plain(rng, model):
    cfg, params = model
    reqs = _requests(rng, cfg, [(14, 4), (22, 4), (9, 3)])
    tr, _, _ = _traced_run(cfg, params, reqs)
    assert validate_events(tr.events) == []


def test_emitted_events_schema_valid_preempt(rng, model):
    """The contended path exercises the decision/host-tier kinds."""
    cfg, params = model
    reqs = _requests(rng, cfg, [(30, 6), (28, 6), (26, 5), (22, 6), (9, 5)])
    tr, _, m = _traced_run(cfg, params, reqs, num_pages=11, preemption="lru",
                           host_kv_bytes=1 << 30, debug_audit=True)
    assert validate_events(tr.events) == []
    assert m.preemptions >= 1
    kinds = {e["kind"] for e in tr.events}
    assert {"preempt", "offload", "restore", "admission_denied"} <= kinds
    # every emitted kind is a known schema kind
    assert kinds <= set(EVENT_SCHEMAS)


def test_validate_event_rejects_bad_events():
    ok = {"kind": "first_token", "t": 0.5, "uid": 1, "ttft_s": 0.5}
    assert validate_event(ok) == []
    assert validate_event({"kind": "nope", "t": 0.0})      # unknown kind
    assert validate_event({"kind": "first_token", "t": 0.5, "uid": 1})
    assert validate_event({**ok, "ttft_s": "fast"})         # wrong type
    assert validate_event({**ok, "bogus": 1})               # extra field
    assert validate_event({**ok, "t": "now"})               # bad timestamp
    assert validate_event({"kind": "trace_header", "v": 999})
    # bools are not ints/nums
    assert validate_event({"kind": "host_evict", "t": 0.0, "bytes": True})


def test_optional_fields_allowed_absent_or_null():
    base = {"kind": "admission_denied", "t": 0.0, "uid": 3,
            "reason": "no_free_slot"}
    assert validate_event(base) == []
    assert validate_event({**base, "pages_needed": None}) == []
    assert validate_event({**base, "pages_needed": 7}) == []


def test_validate_jsonl_roundtrip(tmp_path):
    tr = ServeTracer(clock=FakeClock())
    tr.emit("enqueue", 0.0, uid=0, prompt_len=5, max_new=4)
    tr.emit("host_evict", 0.1, bytes=4096)
    p = str(tmp_path / "t.jsonl")
    tr.to_jsonl(p)
    n, errs = TR.validate_jsonl(p)
    assert (n, errs) == (2, [])


# ---------------------------------------------------------------------------
# Zero perturbation: traced == untraced, bit-identical, across modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["plain", "prefix", "int8", "spec",
                                  "preempt"])
def test_traced_outputs_bit_identical(rng, model, mode):
    cfg, params = model
    import dataclasses
    policy = dataclasses.replace(FP32, kv_dtype="int8") \
        if mode == "int8" else FP32
    prefix = list(map(int, rng.integers(4, 400, size=16))) \
        if mode == "prefix" else None
    shapes = [(14, 5), (25, 5), (9, 4)] if prefix \
        else [(30, 5), (26, 5), (9, 4), (22, 5)]
    reqs = _requests(rng, cfg, shapes, prefix=prefix)
    kw = {}
    if mode == "spec":
        from repro.core.speculative import SpecConfig
        kw["spec"] = SpecConfig(k=3, drafter="ngram")
    if mode == "preempt":
        kw.update(num_pages=11, preemption="lru", host_kv_bytes=1 << 30,
                  debug_audit=True)

    base, _ = _serve(_engine(cfg, params, policy), reqs, **kw)
    tr = ServeTracer()
    done, _ = _serve(_engine(cfg, params, policy), reqs, trace=tr, **kw)
    for uid, out in done.items():
        assert out == base[uid], f"tracing perturbed outputs ({mode})"
    assert validate_events(tr.events) == []


# ---------------------------------------------------------------------------
# Reconciliation: trace totals vs ServeMetrics
# ---------------------------------------------------------------------------


def test_trace_reconciles_with_metrics(rng, model):
    cfg, params = model
    reqs = _requests(rng, cfg, [(18, 5), (24, 5), (11, 4)])
    tr = ServeTracer()
    _, m = _serve(_engine(cfg, params), reqs, trace=tr)
    span_dev = sum(e["dur"] for e in tr.iter_events("span")
                   if e["track"] == "device")
    it_dev = sum(e["device_s"] for e in tr.iter_events("iteration"))
    it_host = sum(e["host_s"] for e in tr.iter_events("iteration"))
    # device spans use the same clock reads that feed prefill_s/decode_s
    assert span_dev == pytest.approx(m.device_s, rel=1e-9, abs=1e-9)
    assert it_dev == pytest.approx(m.device_s, rel=1e-9, abs=1e-9)
    # iteration host time excludes pre/post-loop overhead, so it can only
    # undershoot the metrics' host share
    assert 0.0 <= it_host <= m.host_s + 1e-6
    # lifecycle accounting closes: every request enqueued, admitted, retired
    uids = {r.uid for r in reqs}
    for kind in ("enqueue", "admit", "retire"):
        assert {e["uid"] for e in tr.iter_events(kind)} == uids
    # iteration budget fields respect the configured ceiling
    for e in tr.iter_events("iteration"):
        assert e["budget"] == 16
        assert 0 <= e["budget_used"] <= 16


def test_first_token_matches_metrics_ttft(rng, model):
    cfg, params = model
    reqs = _requests(rng, cfg, [(13, 4), (21, 4)])
    tr = ServeTracer()
    _, m = _serve(_engine(cfg, params), reqs, trace=tr)
    ttfts = sorted(e["ttft_s"] for e in tr.iter_events("first_token"))
    assert len(ttfts) == len(reqs)
    np.testing.assert_allclose(ttfts, sorted(m.ttft_s), rtol=1e-9)


# ---------------------------------------------------------------------------
# Perfetto exporter
# ---------------------------------------------------------------------------


def test_perfetto_structure(rng, model):
    cfg, params = model
    reqs = _requests(rng, cfg, [(14, 4), (22, 4)])
    tr, _, _ = _traced_run(cfg, params, reqs)
    doc = to_perfetto_dict(list(tr.events), dropped=tr.dropped)
    te = doc["traceEvents"]
    assert isinstance(te, list) and te
    assert doc["otherData"]["schema_version"] == TR.TRACE_SCHEMA_VERSION
    names = {e.get("args", {}).get("name") for e in te if e["ph"] == "M"}
    assert {"repro-serve", "scheduler", "device"} <= names
    # balanced B/E nesting per tid (slot occupancy slices)
    depth = {}
    for e in te:
        if e["ph"] == "B":
            depth[e["tid"]] = depth.get(e["tid"], 0) + 1
        elif e["ph"] == "E":
            depth[e["tid"]] = depth.get(e["tid"], 0) - 1
            assert depth[e["tid"]] >= 0
    assert all(v == 0 for v in depth.values())
    # gauges exported as counter tracks, timestamps in microseconds
    counters = {e["name"] for e in te if e["ph"] == "C"}
    assert {"pages_in_use", "host_bytes", "trie_nodes"} <= counters
    assert all(isinstance(e["ts"], (int, float))
               for e in te if "ts" in e)


def test_perfetto_closes_dangling_slices():
    """A preempt without slot (lost record) must not corrupt nesting:
    an admit with no matching end is closed at trace end."""
    evs = [{"kind": "admit", "t": 0.1, "uid": 7, "slot": 0,
            "matched_tokens": 0, "pages": 2, "resume": "no"}]
    doc = to_perfetto_dict(evs)
    phases = [e["ph"] for e in doc["traceEvents"]]
    assert phases.count("B") == phases.count("E") == 1


def test_ring_buffer_drops_and_counts():
    tr = ServeTracer(clock=FakeClock(), ring_size=5)
    for i in range(8):
        tr.emit("host_evict", float(i), bytes=i)
    assert len(tr.events) == 5
    assert tr.dropped == 3
    assert [e["t"] for e in tr.events] == [3.0, 4.0, 5.0, 6.0, 7.0]
    assert tr.header()["dropped"] == 3


# ---------------------------------------------------------------------------
# Shared percentile helper + metrics dump
# ---------------------------------------------------------------------------


def test_percentile_matches_numpy(rng):
    vals = list(rng.uniform(0.0, 10.0, size=37))
    for q in (50, 90, 99):
        assert ServeMetrics.percentile(vals, q) == pytest.approx(
            float(np.percentile(np.asarray(vals), q)))
    assert ServeMetrics.percentile([], 50) == 0.0
    assert ServeMetrics.percentile([3.5], 99) == 3.5


def test_metrics_to_dict_derived_keys():
    m = ServeMetrics(host_s=1.0, device_s=3.0, mixed_iters=4,
                     mixed_dispatches=4, packed_tokens_real=90,
                     packed_tokens_padded=100,
                     latency_s=[1.0, 2.0], ttft_s=[0.1, 0.2],
                     itl_s=[0.01, 0.02])
    d = m.to_dict()
    for k in ("latency_p50", "latency_p99", "ttft_p50", "ttft_p99",
              "itl_p50", "itl_p99", "host_frac", "dispatches_per_iter",
              "padded_token_frac", "decode_idle_frac", "acceptance_rate",
              "tokens_per_forward", "prefix_hit_rate"):
        assert k in d, k
    assert d["host_frac"] == pytest.approx(0.25)
    assert "latency_s" not in d                   # raw lists opt-in only
    assert "latency_s" in m.to_dict(include_raw=True)
    json.dumps(d)                                 # JSON-serializable


# ---------------------------------------------------------------------------
# bench_diff regression gate
# ---------------------------------------------------------------------------


def _synthetic_overload(**over):
    rep = {
        "arch": "qwen3-4b", "requests": 8, "slots": 3, "max_new": 8,
        "trace": "overload",
        "overload": {
            "all_terminal": True, "all_completed": True,
            "outputs_identical_contended": True,
            "contended": {"preemptions": 10, "offloaded_pages": 72,
                          "restored_pages": 72},
        },
    }
    for path, v in over.items():
        cur = rep
        parts = path.split(".")
        for p in parts[:-1]:
            cur = cur[p]
        cur[parts[-1]] = v
    return rep


def test_bench_diff_baseline_vs_itself_passes():
    rep = _synthetic_overload()
    v = bench_diff.diff(rep, rep)
    assert v["kind"] == "overload"
    assert v["config_match"] and v["pass"] and v["n_fail"] == 0


def test_bench_diff_invariant_break_fails():
    base = _synthetic_overload()
    fresh = _synthetic_overload(**{
        "overload.outputs_identical_contended": False})
    v = bench_diff.diff(base, fresh)
    assert not v["pass"]
    bad = [c for c in v["checks"] if c["status"] == "FAIL"]
    assert any("outputs_identical_contended" in c["path"] for c in bad)


def test_bench_diff_relative_regression_fails_on_config_match():
    base = _synthetic_overload()
    fresh = _synthetic_overload(**{"overload.contended.preemptions": 500})
    v = bench_diff.diff(base, fresh)
    assert not v["pass"]          # preemptions ballooned beyond tolerance


def test_bench_diff_config_mismatch_skips_relative_checks():
    base = _synthetic_overload()
    fresh = _synthetic_overload(**{"overload.contended.preemptions": 500})
    fresh["requests"] = 99        # different run shape
    v = bench_diff.diff(base, fresh)
    assert not v["config_match"]
    assert v["pass"]              # invariants hold; relative checks skipped
    assert any(c["status"] == "SKIP" and c["mode"] == "rel"
               for c in v["checks"])


def test_bench_diff_if_present_semantics():
    base = {"arch": "a", "requests": 1, "slots": 1, "max_new": 1,
            "trace": "mixed",
            "outputs_identical_prefix_on_off": True,
            "packed": {"outputs_identical_packed_on_off": True,
                       "packed_on": {"dispatches_per_iter": 1.0,
                                     "padded_token_frac": 0.1,
                                     "prefill_pad_frac": 0.0}}}
    # absent from both baseline and fresh -> SKIP
    v = bench_diff.diff(base, copy.deepcopy(base), kind="serving")
    spec = [c for c in v["checks"]
            if c["path"] == "speculative.outputs_match_nonspec"][0]
    assert spec["status"] == "SKIP"
    # present in baseline, silently dropped from fresh -> FAIL
    base2 = copy.deepcopy(base)
    base2["speculative"] = {"outputs_match_nonspec": True}
    v2 = bench_diff.diff(base2, copy.deepcopy(base), kind="serving")
    spec2 = [c for c in v2["checks"]
             if c["path"] == "speculative.outputs_match_nonspec"][0]
    assert spec2["status"] == "FAIL" and not v2["pass"]


def test_bench_diff_kind_detection():
    assert bench_diff.detect_kind({"overload": {}}) == "overload"
    assert bench_diff.detect_kind({"longprompt": {}}) == "longprompt"
    assert bench_diff.detect_kind({"packed": {}}) == "serving"
