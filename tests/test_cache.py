"""KV-cache correctness (paper P1): prefill+decode == full forward,
ring-buffer windows, ragged batches, MLA latent cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_reduced
from repro.core.precision import FP32
from repro.models import transformer as T

settings.register_profile("cache", deadline=None, max_examples=8)
settings.load_profile("cache")

ARCHS = ["qwen3-4b", "gemma2-2b", "deepseek-v3-671b", "hymba-1.5b",
         "xlstm-125m", "musicgen-medium"]


def _toks(cfg, rng, B, S):
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    return jnp.asarray(rng.integers(4, cfg.vocab_size, size=shape),
                       jnp.int32)


def _decode_fn(cfg, params):
    def step(tok, cache, lens):
        return T.forward_decode(params, cfg, tok, cache, lens, policy=FP32)
    return jax.jit(step)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch, rng, key):
    """Token-by-token decode logits == full-sequence forward logits."""
    cfg = get_reduced(arch)
    params = T.init_params(key, cfg)
    B, S = 2, 12
    toks = _toks(cfg, rng, B, S)
    full, _ = T.forward_train(params, cfg, toks, policy=FP32, remat=False)

    cache = T.init_cache(cfg, B, 64, jnp.float32)
    lens = jnp.full((B,), 4, jnp.int32)
    lg, cache = T.forward_prefill(params, cfg, toks[:, :4], lens, cache,
                                  policy=FP32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :4]),
                               rtol=3e-4, atol=3e-4)
    step = _decode_fn(cfg, params)
    for t in range(4, S):
        lg1, cache = step(toks[:, t:t+1], cache,
                          jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg1[:, 0]), np.asarray(full[:, t]),
            rtol=3e-4, atol=3e-4, err_msg=f"{arch} step {t}")


@pytest.mark.parametrize("arch", ["gemma2-2b", "hymba-1.5b"])
def test_ring_cache_eviction_matches_window(arch, rng, key):
    """With a cache sized to the window, decoding far past the window must
    still match teacher forcing (ring eviction is harmless by masking)."""
    cfg = get_reduced(arch)
    params = T.init_params(key, cfg)
    B, S = 1, 100                        # window in reduced configs is 64
    toks = _toks(cfg, rng, B, S)
    full, _ = T.forward_train(params, cfg, toks, policy=FP32, remat=False)
    cache = T.init_cache(cfg, B, S, jnp.float32)
    lens = jnp.full((B,), 1, jnp.int32)
    _, cache = T.forward_prefill(params, cfg, toks[:, :1], lens, cache,
                                 policy=FP32)
    step = _decode_fn(cfg, params)
    for t in range(1, S):
        lg1, cache = step(toks[:, t:t+1], cache,
                          jnp.full((B,), t, jnp.int32))
        if t > 70:                      # deep past the window
            np.testing.assert_allclose(
                np.asarray(lg1[:, 0]), np.asarray(full[:, t]),
                rtol=5e-4, atol=5e-4, err_msg=f"step {t}")


@given(st.integers(0, 2 ** 31))
def test_ragged_prefill_matches_per_row(seed):
    """Right-padded ragged batch prefill == each row prefilled alone."""
    rng = np.random.default_rng(seed)
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(1), cfg)
    B, S = 3, 10
    lens = rng.integers(1, S + 1, size=B)
    toks = jnp.asarray(rng.integers(4, cfg.vocab_size, size=(B, S)),
                       jnp.int32)
    cache = T.init_cache(cfg, B, 32, jnp.float32)
    lg, cache = T.forward_prefill(params, cfg, toks,
                                  jnp.asarray(lens, jnp.int32), cache,
                                  policy=FP32)
    nxt, cache2 = T.forward_decode(
        params, cfg, toks[:, :1], cache, jnp.asarray(lens, jnp.int32),
        policy=FP32)
    for b in range(int(B)):
        lb = int(lens[b])
        c1 = T.init_cache(cfg, 1, 32, jnp.float32)
        lg1, c1 = T.forward_prefill(params, cfg, toks[b:b+1, :lb],
                                    jnp.asarray([lb], jnp.int32), c1,
                                    policy=FP32)
        np.testing.assert_allclose(np.asarray(lg[b, :lb]),
                                   np.asarray(lg1[0]),
                                   rtol=3e-4, atol=3e-4)
        n1, _ = T.forward_decode(params, cfg, toks[b:b+1, :1], c1,
                                 jnp.asarray([lb], jnp.int32), policy=FP32)
        np.testing.assert_allclose(np.asarray(nxt[b]), np.asarray(n1[0]),
                                   rtol=3e-4, atol=3e-4)


def test_long_context_override_ring_bounded(rng, key):
    """The beyond-paper long_500k sliding-window override: past the native
    context, global attention layers get a bounded ring cache, and decode
    matches teacher forcing *within the override window*."""
    from repro.core import kv_cache as KVC
    from repro.configs.base import LayerSpec
    cfg = get_reduced("phi3-mini-3.8b").replace(
        long_context_override=32, native_context=48)
    spec = LayerSpec()                      # global attention layer
    # below native context: full cache, no window
    assert KVC.effective_window(cfg, spec, 40) is None
    # beyond native context: override window applies, ring-bounded alloc
    assert KVC.effective_window(cfg, spec, 128) == 32
    c = KVC.layer_cache_shape(cfg, spec, 1, 128, jnp.float32)
    assert c["k"].shape[1] <= 33 + 255      # window+dump, 256-rounded

    # teacher-forcing equivalence with a window-limited reference:
    # compare decode (ring cache) vs full forward where positions beyond
    # the window are excluded by construction of the mask
    params = T.init_params(key, cfg)
    B, S = 1, 96
    toks = _toks(cfg, rng, B, S)
    cache = T.init_cache(cfg, B, 128, jnp.float32)   # 128 > native 48
    lens = jnp.full((B,), 1, jnp.int32)
    _, cache = T.forward_prefill(params, cfg, toks[:, :1], lens, cache,
                                 policy=FP32, max_len=128)
    step = _decode_fn(cfg, params)
    outs = []
    for t in range(1, S):
        lg1, cache = step(toks[:, t:t+1], cache,
                          jnp.full((B,), t, jnp.int32))
        outs.append(lg1[:, 0])
    # reference: full forward with the SAME effective window everywhere
    cfg_win = cfg.replace(stacks=tuple(
        type(st)(tuple(LayerSpec(mixer=sp.mixer, ffn=sp.ffn, window=32)
                       for sp in st.pattern), st.repeats)
        for st in cfg.stacks))
    full, _ = T.forward_train(params, cfg_win, toks, policy=FP32,
                              remat=False)
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, 1:]),
                               rtol=5e-4, atol=5e-4)


def test_prefill_last_only_matches_full(rng, key):
    cfg = get_reduced("gemma3-27b")
    params = T.init_params(key, cfg)
    B, S = 2, 9
    toks = _toks(cfg, rng, B, S)
    lens = jnp.asarray([S, S - 3], jnp.int32)
    c1 = T.init_cache(cfg, B, 32, jnp.float32)
    lg_all, _ = T.forward_prefill(params, cfg, toks, lens, c1, policy=FP32)
    c2 = T.init_cache(cfg, B, 32, jnp.float32)
    lg_last, _ = T.forward_prefill(params, cfg, toks, lens, c2, policy=FP32,
                                   last_only=True)
    picked = np.stack([np.asarray(lg_all)[b, int(lens[b]) - 1]
                       for b in range(B)])
    np.testing.assert_allclose(np.asarray(lg_last[:, 0]), picked,
                               rtol=1e-5, atol=1e-5)


def test_cache_struct_matches_init(key):
    cfg = get_reduced("hymba-1.5b")
    struct = T.cache_struct(cfg, 2, 64)
    real = T.init_cache(cfg, 2, 64)
    s_shapes = jax.tree.map(lambda s: (s.shape, str(s.dtype)), struct)
    r_shapes = jax.tree.map(lambda a: (a.shape, str(a.dtype)), real)
    assert s_shapes == r_shapes
