"""Serve-time int8 weight-only quantization (weights_dtype policy axis).

Contracts under test:
  * quantize/dequantize round-trip error is bounded by half a
    quantization step at each output channel's absmax scale;
  * ``weights_store_dtype`` resolves the policy axis (and rejects
    unknown values);
  * the fused-dequant Pallas matmul kernel (interpret mode) matches the
    fp32 oracle on tile-aligned AND non-tile-multiple shapes;
  * ``compress_weights`` rewrites exactly the serve-path dense matmul
    set — attention qkv/out, dense FFN, the unembed head — and leaves
    MoE expert stacks (router present) and the embedding gather table
    untouched; tied-embedding archs gain a separate quantized head;
  * weight_bytes accounting: int8 codes + fp32 scales land near 1/4 of
    the fp32 dense bytes (a bit above — the scales);
  * serving with int8 weights works on every execution path — bucketed
    admission, fused decode, mixed chunked, token-packed, speculative
    verify — with identical greedy outputs across paths, matching the
    fp32 reference on the committed smoke trace;
  * the Pallas kernel path (interpret mode) is greedy-bit-identical to
    the jnp fallback through the full serve loop;
  * ServeMetrics weight fields and zero-guards.
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core.continuous import ServeMetrics
from repro.core.engine import InferenceEngine
from repro.core.precision import (FP32, compress_weights,
                                  dequantize_weights, is_quantized_weight,
                                  quantize_weights, weights_store_dtype)
from repro.core.scheduler import Request
from repro.kernels import ops as KOPS
from repro.kernels import quant_matmul as QM
from repro.kernels import ref as KREF
from repro.models import transformer as T

W8 = dataclasses.replace(FP32, weights_dtype="int8")


def _trace(rng, spec=((6, 4), (12, 4), (9, 3))):
    return [Request(uid=i, tokens=[2] + list(map(int, rng.integers(
        4, 400, size=ln))), max_new_tokens=mn)
        for i, (ln, mn) in enumerate(spec)]


# ---------------------------------------------------------------------------
# Quantize / dequantize primitives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(16, 8), (300, 520), (2, 64, 48)])
def test_weight_quant_roundtrip_error_bound(rng, shape):
    """|dequant(quant(w)) - w| <= absmax(col)/127/2 per element (half a
    quantization step at the output channel's scale)."""
    w = jnp.asarray(rng.normal(size=shape) * 2.0, jnp.float32)
    rec = quantize_weights(w)
    assert is_quantized_weight(rec)
    assert rec["q"].dtype == jnp.int8 and rec["s"].dtype == jnp.float32
    assert rec["q"].shape == shape
    assert rec["s"].shape == shape[:-2] + shape[-1:]
    back = np.asarray(dequantize_weights(rec))
    bound = np.abs(np.asarray(w)).max(axis=-2, keepdims=True) / 127.0 / 2.0
    assert (np.abs(back - np.asarray(w)) <= bound + 1e-7).all()


def test_weight_quant_zero_columns(rng):
    z = jnp.zeros((8, 4), jnp.float32)
    rec = quantize_weights(z)
    assert (np.asarray(rec["q"]) == 0).all()
    assert (np.asarray(rec["s"]) == 0).all()
    assert (np.asarray(dequantize_weights(rec)) == 0).all()


def test_weights_store_dtype_resolution():
    assert weights_store_dtype("auto", jnp.bfloat16) == jnp.bfloat16
    assert weights_store_dtype("bf16", jnp.float32) == jnp.bfloat16
    assert weights_store_dtype("fp16", jnp.float32) == jnp.float16
    assert weights_store_dtype("int8", jnp.float32) == jnp.int8
    with pytest.raises(ValueError):
        weights_store_dtype("int4", jnp.float32)


# ---------------------------------------------------------------------------
# Pallas kernel vs fp32 oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [
    (32, 128, 128),        # exactly one tile
    (64, 256, 256),        # multi-tile, aligned
    (1, 256, 200),         # decode row (M pads 1 -> 32), ragged N
    (7, 130, 257),         # off-by-one over tile edges
    (33, 128, 129),
])
def test_quant_matmul_kernel_matches_oracle(rng, m, k, n):
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    rec = quantize_weights(jnp.asarray(rng.normal(size=(k, n)),
                                       jnp.float32))
    assert QM.shape_supported(x, rec["q"], rec["s"])
    out = QM.quant_matmul(x, rec["q"], rec["s"], interpret=True)
    ref = KREF.quant_matmul_ref(x, rec["q"], rec["s"])
    assert out.shape == (m, n) and out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_quant_matmul_kernel_batched_lead_dims(rng):
    """(B, S, K) activations flatten through the kernel unchanged."""
    x = jnp.asarray(rng.normal(size=(3, 5, 96)), jnp.float32)
    rec = quantize_weights(jnp.asarray(rng.normal(size=(96, 72)),
                                       jnp.float32))
    out = QM.quant_matmul(x, rec["q"], rec["s"], interpret=True)
    ref = KREF.quant_matmul_ref(x, rec["q"], rec["s"])
    assert out.shape == (3, 5, 72)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_quant_matmul_shape_guards(rng):
    rec = quantize_weights(jnp.asarray(rng.normal(size=(16, 8)),
                                       jnp.float32))
    x = jnp.asarray(rng.normal(size=(2, 16)), jnp.float32)
    assert not QM.shape_supported(x[0], rec["q"], rec["s"])     # 1-D x
    assert not QM.shape_supported(x, rec["q"].astype(jnp.int32),
                                  rec["s"])                     # not int8
    assert not QM.shape_supported(
        jnp.zeros((2, 17), jnp.float32), rec["q"], rec["s"])    # K mismatch
    # pathological padding blowup is refused (1x1 weight -> 128x128 tile)
    tiny = quantize_weights(jnp.ones((1, 1), jnp.float32))
    assert not QM.shape_supported(jnp.ones((1, 1), jnp.float32),
                                  tiny["q"], tiny["s"])


def test_dispatcher_off_mode_returns_none(rng):
    rec = quantize_weights(jnp.asarray(rng.normal(size=(256, 256)),
                                       jnp.float32))
    x = jnp.asarray(rng.normal(size=(8, 256)), jnp.float32)
    with KOPS.kernel_mode_ctx("off"):
        assert KOPS.maybe_quant_matmul(x, rec["q"], rec["s"]) is None
    with KOPS.kernel_mode_ctx("interpret"):
        out = KOPS.maybe_quant_matmul(x, rec["q"], rec["s"])
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(KREF.quant_matmul_ref(x, rec["q"], rec["s"])),
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# compress_weights: structure + byte accounting
# ---------------------------------------------------------------------------


def test_compress_weights_structure_untied(key):
    cfg = get_reduced("phi3-mini-3.8b")
    assert not cfg.tie_embeddings
    params = T.init_params(key, cfg)
    comp, stats = compress_weights(params, W8)
    assert stats["weights_dtype"] == "int8"
    assert stats["n_quantized"] > 0
    # untied: the unembed head quantizes in place; the gather table and
    # norm weights stay full precision
    assert is_quantized_weight(comp["embed"]["head"])
    assert not isinstance(comp["embed"]["tokens"], dict)
    assert not isinstance(comp["final_norm"]["w"], dict)
    blk = comp["stacks"][0][0]
    for k in ("wq", "wk", "wv", "wo"):
        assert is_quantized_weight(blk["attn"][k])
    assert is_quantized_weight(blk["ffn"]["wi"])
    # int8 codes + fp32 scales vs fp32 dense: near 1/4, scales on top
    assert stats["weight_bytes"] < 0.3 * stats["weight_bytes_dense"]
    assert stats["weight_bytes"] + stats["weight_bytes_saved"] \
        == stats["weight_bytes_dense"]
    # the original tree is untouched (fresh containers, not mutation)
    assert not isinstance(params["embed"]["head"], dict)


def test_compress_weights_structure_tied(key):
    cfg = get_reduced("qwen3-4b")
    assert cfg.tie_embeddings
    comp, stats = compress_weights(T.init_params(key, cfg), W8)
    # tied: the gather table stays dense (exact lookups); a SEPARATE
    # transposed quantized head carries the unembed matmul
    assert not isinstance(comp["embed"]["tokens"], dict)
    assert is_quantized_weight(comp["embed"]["head_q8"])
    d, v = comp["embed"]["tokens"].shape[::-1]
    assert comp["embed"]["head_q8"]["q"].shape == (d, v)
    assert stats["n_quantized"] > 0


def test_compress_weights_skips_moe_experts(key):
    cfg = get_reduced("qwen3-moe-235b-a22b")
    comp, stats = compress_weights(T.init_params(key, cfg), W8)
    ffn = comp["stacks"][0][0]["ffn"]
    # expert stacks feed ragged_dot and must stay dense arrays
    assert "router" in ffn
    for k in ("router", "wi", "wg", "wo"):
        assert not isinstance(ffn[k], dict)
    # attention + head still quantize
    assert is_quantized_weight(comp["stacks"][0][0]["attn"]["wq"])
    assert is_quantized_weight(comp["embed"]["head"])
    assert stats["n_quantized"] > 0


def test_compress_weights_noop_modes(key):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(key, cfg)
    same, stats = compress_weights(params, FP32)       # auto = no-op
    assert stats["n_quantized"] == 0
    assert stats["weight_bytes"] == stats["weight_bytes_dense"]
    assert same["stacks"][0][0]["attn"]["wq"] is \
        params["stacks"][0][0]["attn"]["wq"]
    # bf16 storage halves bytes without records (exactly half on an
    # untied arch; tied archs keep the shared gather table fp32)
    up = T.init_params(key, get_reduced("phi3-mini-3.8b"))
    bf, bst = compress_weights(
        up, dataclasses.replace(FP32, weights_dtype="bf16"))
    assert bf["stacks"][0][0]["attn"]["wq"].dtype == jnp.bfloat16
    assert bf["embed"]["head"].dtype == jnp.bfloat16
    assert bst["weight_bytes"] * 2 == bst["weight_bytes_dense"]


# ---------------------------------------------------------------------------
# Serving: every execution path, int8 weights
# ---------------------------------------------------------------------------


def test_int8_weights_serve_all_paths_match_fp32(rng):
    """The committed smoke trace on qwen3-4b: int8-weight greedy outputs
    match fp32 on every execution path, and all paths agree with each
    other.  (Per-request agreement with fp32 is workload-dependent in
    general — sub-quantization-noise greedy margins can flip — but this
    deterministic trace matches exactly and pins the behavior.)"""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _trace(rng)
    modes = {
        "bucketed": dict(chunked_prefill=False),
        "fused_decode": dict(chunked_prefill=False, steps_per_sync=3),
        "mixed": dict(max_batched_tokens=16, packed=False),
        "packed": dict(max_batched_tokens=16, packed=True),
    }
    outs = {}
    for name, kw in modes.items():
        for pol, tag in ((FP32, "fp"), (W8, "q8")):
            eng = InferenceEngine(cfg, params, policy=pol, max_len=64,
                                  max_batch=2)
            done, m = eng.serve_continuous(copy.deepcopy(reqs),
                                           page_size=8, prefix_cache=True,
                                           **kw)
            outs[(name, tag)] = [r.result for r in done]
            assert all(r.result for r in done)
            if tag == "q8":
                assert m.weight_dtype == "int8"
                assert m.weight_bytes > 0
                assert m.weight_bytes_saved > m.weight_bytes * 2
    for name in modes:
        assert outs[(name, "q8")] == outs[(name, "fp")], name
    base = outs[("bucketed", "q8")]
    for name in modes:
        assert outs[(name, "q8")] == base, name


def test_int8_weights_spec_verify_path(rng):
    """Speculative verify runs through the quantized unembed/qkv path
    and stays bit-identical to non-speculative int8 serving."""
    from repro.core.speculative import SpecConfig
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _trace(rng, spec=((8, 6), (14, 6)))
    base, _ = InferenceEngine(cfg, params, policy=W8, max_len=64,
                              max_batch=2).serve_continuous(
        copy.deepcopy(reqs), page_size=8, prefix_cache=False)
    spec, m = InferenceEngine(cfg, params, policy=W8, max_len=64,
                              max_batch=2).serve_continuous(
        copy.deepcopy(reqs), page_size=8, prefix_cache=False,
        spec=SpecConfig(k=3, drafter="ngram"))
    assert [r.result for r in spec] == [r.result for r in base]
    assert m.spec_mode == "ngram"


def test_int8_weights_kernel_vs_fallback_bit_identical(rng):
    """kernel_mode interpret (Pallas quant matmul) vs off (jnp
    fallback): the serve loop's greedy streams must be bit-identical —
    both paths accumulate codes in fp32 and rescale once per column."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _trace(rng)
    eng = InferenceEngine(cfg, params, policy=W8, max_len=64, max_batch=2)
    base, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   max_batched_tokens=16,
                                   prefix_cache=True)
    eng2 = InferenceEngine(cfg, params, policy=W8, max_len=64, max_batch=2)
    with KOPS.kernel_mode_ctx("interpret"):
        done, _ = eng2.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                        max_batched_tokens=16,
                                        prefix_cache=True)
    for a, b in zip(base, done):
        assert a.result == b.result


def test_weights_trace_event_and_span(rng):
    """Traced int8 serving emits a schema-valid 'weights' event and a
    load-time quantize_weights span on the 'load' track (never the
    device track — its sum must keep reconciling with device_s)."""
    from repro.core.trace import ServeTracer, validate_events
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tr = ServeTracer()
    eng = InferenceEngine(cfg, params, policy=W8, max_len=64, max_batch=2)
    eng.serve_continuous(_trace(rng), page_size=8,
                         max_batched_tokens=16, trace=tr)
    assert validate_events(tr.events) == []
    wev = [e for e in tr.events if e["kind"] == "weights"]
    assert len(wev) == 1
    assert wev[0]["dtype"] == "int8"
    assert 0 < wev[0]["weight_bytes"] < wev[0]["weight_bytes_dense"]
    spans = [e for e in tr.events if e["kind"] == "span"
             and e["name"] == "quantize_weights"]
    assert len(spans) == 1 and spans[0]["track"] == "load"
    # fp32 runs emit no quantize span (byte-determinism of fake-clock
    # traces) but still stamp the weights gauge
    tr2 = ServeTracer()
    InferenceEngine(cfg, params, policy=FP32, max_len=64,
                    max_batch=2).serve_continuous(
        _trace(rng), page_size=8, max_batched_tokens=16, trace=tr2)
    assert not [e for e in tr2.events if e["kind"] == "span"
                and e["name"] == "quantize_weights"]
    assert validate_events(tr2.events) == []


# ---------------------------------------------------------------------------
# Metrics guards
# ---------------------------------------------------------------------------


def test_servemetrics_weight_defaults_and_dict():
    m = ServeMetrics()
    assert m.weight_dtype == "auto"
    assert m.weight_bytes == 0 and m.weight_bytes_saved == 0
    assert m.host_syncs == 0
    d = m.to_dict()
    for k in ("weight_dtype", "weight_bytes", "weight_bytes_saved",
              "host_syncs"):
        assert k in d


def test_host_syncs_counted_per_iteration(rng):
    """On the coalesced mixed path every iteration blocks exactly once,
    so host_syncs stays at/below the dispatch count and above zero."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                          max_batch=2)
    _, m = eng.serve_continuous(_trace(rng), page_size=8,
                                max_batched_tokens=16, packed=False,
                                prefix_cache=True)
    assert 0 < m.host_syncs <= m.mixed_iters + m.steps
    assert m.host_syncs < m.mixed_dispatches + m.steps
