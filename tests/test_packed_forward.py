"""Token-packed ragged execution: one (1, T) dispatch per iteration.

Contracts under test:

  * the packed paged-attention kernel matches its oracle on ragged
    multi-slot streams (decode lanes + prefill chunks in one (1, T)
    dispatch), including int8 pools, sliding windows and logit
    softcaps; padding lanes (q_pos == -1) come back exactly zero;
  * ``forward_packed`` reproduces ``forward_decode`` /
    ``forward_prefill`` logits for the same tokens — the whole
    iteration flattens without changing any segment's math;
  * ``pack_batch`` preserves the plan verbatim (budget ceiling,
    decode-first layout, FCFS chunk order, contiguous per-segment
    positions) — property-tested over random scheduler traces;
  * greedy serving outputs are bit-identical between packed and
    bucketed execution across plain, shared-prefix, int8, speculative
    and preemption/resume serving, while packed runs make exactly ONE
    device dispatch per mixed iteration;
  * the new ServeMetrics derivations (host/device split, dispatches
    per iteration, padded-token fraction) are zero-guarded.
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core.continuous import (ContinuousScheduler, PageAllocator,
                                   ServeMetrics)
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.scheduler import Request
from repro.kernels import decode_attention as DA
from repro.kernels import ref as R
from repro.models import transformer as T

INT8 = dataclasses.replace(FP32, kv_dtype="int8")


# ---------------------------------------------------------------------------
# Packed paged-attention kernel vs oracle
# ---------------------------------------------------------------------------


def _packed_setup(rng, *, q8=False):
    """Three slots' paged pools plus one packed stream over them:
    a decode lane for slot 0, a 5-token chunk for slot 1 and a 6-token
    chunk for slot 2, padded to T=16."""
    B, P, page, npages, Hq, Hkv, D = 3, 10, 8, 3, 4, 2, 16
    T_ = 16
    ctx = [9, 14, 4]                   # already-stored context per slot
    seg_len = [1, 5, 6]                # decode, chunk, chunk
    if q8:
        kpool = jnp.asarray(rng.integers(-127, 128, size=(P, page, Hkv, D)),
                            jnp.int8)
        vpool = jnp.asarray(rng.integers(-127, 128, size=(P, page, Hkv, D)),
                            jnp.int8)
        k_scale = jnp.asarray(rng.uniform(0.01, 0.1, size=(P, page, Hkv)),
                              jnp.float32)
        v_scale = jnp.asarray(rng.uniform(0.01, 0.1, size=(P, page, Hkv)),
                              jnp.float32)
    else:
        kpool = jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32)
        vpool = jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32)
        k_scale = v_scale = None
    ppos = np.full((P, page), -1, np.int32)
    bt = np.full((B, npages), -1, np.int32)
    perm = rng.permutation(P - 1)      # last page is the dump
    nxt = 0
    for b in range(B):
        used = -(-(ctx[b] + seg_len[b]) // page)
        bt[b, :used] = perm[nxt:nxt + used]
        nxt += used
        for t in range(ctx[b] + seg_len[b]):   # window K/V already written
            ppos[bt[b, t // page], t % page] = t
    slot_ids = np.full(T_, -1, np.int32)
    q_pos = np.full(T_, -1, np.int32)
    seg_start, t = [], 0
    for b in range(B):
        seg_start.append(t)
        slot_ids[t:t + seg_len[b]] = b
        q_pos[t:t + seg_len[b]] = ctx[b] + np.arange(seg_len[b])
        t += seg_len[b]
    meta = DA.packed_meta_table(np.asarray(seg_start, np.int32),
                                np.asarray(seg_len, np.int32),
                                np.arange(B, dtype=np.int32), T_,
                                T_ // DA.PACKED_BLOCK_Q + B)
    q = jnp.asarray(rng.normal(size=(1, T_, Hq, D)), jnp.float32)
    return (q, kpool, vpool, jnp.asarray(ppos), jnp.asarray(bt),
            jnp.asarray(q_pos[None, :]), jnp.asarray(slot_ids),
            jnp.asarray(meta), k_scale, v_scale, D, t)


@pytest.mark.parametrize("window,softcap", [(None, None), (None, 30.0),
                                            (4, None)])
def test_packed_kernel_vs_oracle(rng, window, softcap):
    (q, kpool, vpool, ppos, bt, q_pos, slot_ids, meta,
     _, _, D, n_real) = _packed_setup(rng)
    assert DA.paged_packed_shape_supported(q, kpool, bt)
    out = DA.paged_packed_attention(q, kpool, vpool, ppos, bt, q_pos, meta,
                                    window=window, scale=D ** -0.5,
                                    attn_softcap=softcap, interpret=True)
    ref = R.paged_packed_attention_ref(q, kpool, vpool, ppos, bt, q_pos,
                                       slot_ids, window=window,
                                       scale=D ** -0.5, attn_softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # padding lanes are exactly zero
    assert not np.asarray(out[0, n_real:]).any()


def test_packed_kernel_q8_vs_oracle(rng):
    (q, kpool, vpool, ppos, bt, q_pos, slot_ids, meta,
     k_scale, v_scale, D, n_real) = _packed_setup(rng, q8=True)
    out = DA.paged_packed_attention_q8(q, kpool, k_scale, vpool, v_scale,
                                       ppos, bt, q_pos, meta, window=None,
                                       scale=D ** -0.5, interpret=True)
    ref = R.paged_packed_attention_ref(q, kpool, vpool, ppos, bt, q_pos,
                                       slot_ids, window=None,
                                       scale=D ** -0.5, k_scale=k_scale,
                                       v_scale=v_scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert not np.asarray(out[0, n_real:]).any()


def test_packed_meta_table_tiles_segments():
    """Every real lane lands in exactly one query window; unused rows
    are slot -1; tile starts clamp into the padded stream."""
    seg_start = np.asarray([0, 1, 6], np.int32)
    seg_len = np.asarray([1, 5, 6], np.int32)
    seg_slot = np.asarray([0, 1, 2], np.int32)
    T_, bq = 16, DA.PACKED_BLOCK_Q
    meta = DA.packed_meta_table(seg_start, seg_len, seg_slot, T_,
                                T_ // bq + 3)
    covered = np.zeros(T_, int)
    for slot, tile, ws, we in meta:
        if slot < 0:
            assert (tile, ws, we) == (0, 0, 0)
            continue
        assert 0 <= tile <= T_ - bq                 # tile fits the stream
        assert tile <= ws and we <= tile + bq       # window inside tile
        covered[ws:we] += 1
    assert (covered[:12] == 1).all()                # real lanes once each
    assert (covered[12:] == 0).all()                # padding never covered


# ---------------------------------------------------------------------------
# forward_packed vs forward_decode / forward_prefill logits
# ---------------------------------------------------------------------------


def test_forward_packed_matches_decode_and_prefill(rng):
    """One forward_packed stream carrying a decode lane (slot 0) and a
    prefill-chunk segment (slot 1) reproduces forward_decode /
    forward_prefill logits for the same tokens."""
    from repro.core import kv_cache as KV
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    page, npages, slots = 8, 8, 2
    toks = [list(map(int, rng.integers(4, 400, size=9))),
            list(map(int, rng.integers(4, 400, size=13)))]

    def fresh():
        return T.init_paged_cache(cfg, num_pages=npages, page_size=page,
                                  max_slots=slots, max_len=48,
                                  dtype=jnp.float32)

    bt = np.full((slots, 6), -1, np.int32)
    bt[0, :3] = [0, 1, 2]
    bt[1, :3] = [3, 4, 5]
    paged = {"block_tables": jnp.asarray(bt)}

    # reference: slot 0 prefilled whole then one decode step; slot 1's
    # whole-prompt last-token logits
    cache = fresh()
    tok0 = jnp.asarray([toks[0] + [0] * 7, toks[1] + [0] * 3], jnp.int32)
    plens = jnp.asarray([9, 13], jnp.int32)
    lg_p, cache = T.forward_prefill(
        params, cfg, tok0, plens, cache, policy=FP32, max_len=48,
        last_only=True, paged={**paged, "active": jnp.ones((2,), bool)})
    nxt0 = int(jnp.argmax(lg_p[0, 0]))
    lg_d, cache = T.forward_decode(
        params, cfg, jnp.asarray([[nxt0], [0]], jnp.int32), cache,
        jnp.asarray([9, 13], jnp.int32), policy=FP32, max_len=48,
        paged={**paged, "active": jnp.asarray([True, False])})

    # packed: slot 0 decode lane + slot 1's final 5-token chunk, one
    # (1, 8) stream (first 8 of slot 1 pre-written by a prefix call)
    cache2 = fresh()
    _, cache2 = T.forward_prefill(
        params, cfg, tok0, plens, cache2, policy=FP32, max_len=48,
        last_only=True, paged={**paged, "active": jnp.ones((2,), bool)})
    cache2 = KV.reset_pages_all(cache2, np.asarray(bt[1, :3]))
    _, cache2 = T.forward_prefill(
        params, cfg, jnp.asarray([toks[1][:8] + [0] * 5], jnp.int32),
        jnp.asarray([8], jnp.int32), KV.slot_view(cache2, 1), policy=FP32,
        max_len=48, last_only=True,
        paged={"block_tables": jnp.asarray(bt[1:2]),
               "active": jnp.ones((1,), bool)})
    stream = np.zeros(8, np.int32)
    stream[0] = nxt0
    stream[1:6] = toks[1][8:]
    slot_ids = np.asarray([0, 1, 1, 1, 1, 1, -1, -1], np.int32)
    positions = np.asarray([9, 8, 9, 10, 11, 12, -1, -1], np.int32)
    seg_last = np.asarray([0, 5], np.int32)
    lg_pk, _ = T.forward_packed(
        params, cfg, jnp.asarray(stream[None, :]), cache2,
        jnp.asarray(slot_ids), jnp.asarray(positions),
        jnp.asarray(seg_last), policy=FP32, max_len=48, paged=paged)
    np.testing.assert_allclose(np.asarray(lg_pk[0, 0]),
                               np.asarray(lg_d[0, 0]), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(lg_pk[0, 1]),
                               np.asarray(lg_p[1, 0]), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# pack_batch property tests: packing preserves the plan verbatim
# ---------------------------------------------------------------------------


def _pack_invariant_trace(seed: int):
    rng = np.random.default_rng(seed)
    slots = int(rng.integers(2, 6))
    budget = int(rng.integers(slots, 40))
    sched = ContinuousScheduler(slots, PageAllocator(64), page_size=8,
                                max_pages_per_slot=16)
    for uid in range(int(rng.integers(3, 12))):
        sched.submit(Request(uid=uid,
                             tokens=list(map(int, rng.integers(
                                 1, 900, size=int(rng.integers(1, 50))))),
                             max_new_tokens=int(rng.integers(1, 6))))
    pending = rng.integers(1, 900, size=slots).astype(np.int32)
    lengths = rng.integers(1, 64, size=slots).astype(np.int32)
    iters = 0
    while sched.has_work():
        iters += 1
        assert iters < 5000
        while sched.try_admit() is not None:
            pass
        plan = sched.next_batch(budget)
        width = max(8, 1 << (plan.total_tokens - 1).bit_length())
        pb = sched.pack_batch(plan, pending, lengths, width)
        # stream totals track the plan: budget is a hard ceiling
        assert pb.n_tokens == plan.total_tokens <= budget
        assert pb.n_segments == len(plan.decode_slots) + len(plan.chunks)
        assert pb.n_decode == len(plan.decode_slots)
        # decode lanes first, in plan order, one token each
        for i, s in enumerate(plan.decode_slots):
            assert pb.seg_slots[i] == s and pb.seg_len[i] == 1
            assert pb.slot_ids[pb.seg_start[i]] == s
            assert pb.tokens[pb.seg_start[i]] == pending[s]
            assert pb.positions[pb.seg_start[i]] == lengths[s]
        # then FCFS chunks, contiguous positions, prompt tokens verbatim
        for j, c in enumerate(plan.chunks):
            i = pb.n_decode + j
            st = sched.slots[c.slot]
            s0, ln = pb.seg_start[i], pb.seg_len[i]
            assert pb.seg_slots[i] == c.slot and ln == c.length
            assert pb.last_idx[i] == s0 + ln - 1
            assert (pb.slot_ids[s0:s0 + ln] == c.slot).all()
            np.testing.assert_array_equal(
                pb.positions[s0:s0 + ln],
                np.arange(c.start, c.start + c.length))
            np.testing.assert_array_equal(
                pb.tokens[s0:s0 + ln],
                st.ctx[c.start:c.start + c.length])
        # chunk segments keep admission (FCFS) order
        seqs = [sched.slots[c.slot].admit_seq for c in plan.chunks]
        assert seqs == sorted(seqs)
        # segments are contiguous and padding lanes are inert
        starts = [pb.seg_start[i] for i in range(pb.n_segments)]
        assert starts == sorted(starts)
        assert (pb.slot_ids[pb.n_tokens:] == -1).all()
        assert (pb.positions[pb.n_tokens:] == -1).all()
        for c in plan.chunks:                 # apply the plan
            sched.slots[c.slot].prefill_pos += c.length
        for s in plan.decode_slots:
            st = sched.slots[s]
            st.emitted.append(7)
            if len(st.emitted) >= st.request.max_new_tokens:
                sched.retire(s)
    sched.allocator.check()


def test_pack_batch_invariants_seeded():
    for seed in range(50):
        _pack_invariant_trace(seed)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=50)
    @given(st.integers(0, 10_000))
    def test_pack_batch_invariants_hypothesis(seed):
        _pack_invariant_trace(seed)


# ---------------------------------------------------------------------------
# Greedy parity sweep: packed == bucketed execution, one dispatch/iter
# ---------------------------------------------------------------------------


def _requests(rng, cfg, lens_new, prefix=None):
    prefix = prefix or []
    return [Request(uid=i,
                    tokens=[2] + prefix + list(map(int, rng.integers(
                        4, min(cfg.vocab_size, 400), size=ln))),
                    max_new_tokens=mn)
            for i, (ln, mn) in enumerate(lens_new)]


def _serve(eng, reqs, **kw):
    done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   max_batched_tokens=16,
                                   chunked_prefill=True, **kw)
    return {r.uid: r.result for r in done}, m


@pytest.mark.parametrize("mode", ["plain", "prefix", "int8", "spec",
                                  "preempt"])
def test_packed_vs_bucketed_bit_identical(rng, mode):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    policy = INT8 if mode == "int8" else FP32
    prefix = list(map(int, rng.integers(4, 400, size=16))) \
        if mode == "prefix" else None
    shapes = [(14, 6), (25, 6), (9, 5), (19, 6)] if prefix \
        else [(30, 6), (28, 6), (26, 5), (22, 6), (9, 5)]
    reqs = _requests(rng, cfg, shapes, prefix=prefix)
    kw = {}
    if mode == "spec":
        from repro.core.speculative import SpecConfig
        kw["spec"] = SpecConfig(k=3, drafter="ngram")
    if mode == "preempt":
        kw.update(num_pages=11, preemption="lru", host_kv_bytes=1 << 30,
                  debug_audit=True)

    def eng():
        return InferenceEngine(cfg, params, policy=policy, max_len=64,
                               max_batch=3)

    base, mb = _serve(eng(), reqs, packed=False, **kw)
    done, mp = _serve(eng(), reqs, packed=True, **kw)
    for uid, out in done.items():
        assert out == base[uid], f"packed diverged ({mode}, uid {uid})"
    # packed: exactly ONE device dispatch per mixed iteration; bucketed:
    # one per chunk plus the decode micro-step
    assert mp.mixed_iters > 0
    assert mp.dispatches_per_iter == 1.0
    assert mb.dispatches_per_iter > 1.0
    assert 0.0 <= mp.padded_token_frac < 1.0
    assert mp.packed_tokens_real > 0
    assert mb.packed_tokens_real == 0      # bucketed leg never packs
    if mode == "preempt":
        assert mp.preemptions >= 1


def test_packed_kernel_interpret_matches_fallback(rng):
    """The packed Pallas kernel (interpret mode) must not change greedy
    outputs vs the per-lane gather + jnp fallback."""
    from repro.kernels import ops as KOPS
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, cfg, [(19, 4), (27, 4)])
    base, _ = _serve(InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                     max_batch=2), reqs)
    with KOPS.kernel_mode_ctx("interpret"):
        done, _ = _serve(InferenceEngine(cfg, params, policy=FP32,
                                         max_len=64, max_batch=2), reqs)
    for uid, out in done.items():
        assert out == base[uid]


def test_packed_optout_without_chunking_warns(rng):
    """packed=True on a family without chunked-prefill support warns
    and serves bucketed, exactly."""
    cfg = get_reduced("gemma2-2b")            # sliding-window ring
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, cfg, [(9, 4), (17, 4)])
    base, _ = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                              max_batch=2).serve_continuous(
        copy.deepcopy(reqs), page_size=8, chunked_prefill=False)
    base = {r.uid: r.result for r in base}
    with pytest.warns(UserWarning, match="packed execution requested"):
        done, m = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                  max_batch=2).serve_continuous(
            copy.deepcopy(reqs), page_size=8, packed=True)
    assert m.scheduler == "bucketed" and m.packed_tokens_padded == 0
    for r in done:
        assert r.result == base[r.uid]


# ---------------------------------------------------------------------------
# Metrics: host/device split + packed derivations, zero-guarded
# ---------------------------------------------------------------------------


def test_packed_metrics_zero_guards():
    m = ServeMetrics()
    assert m.host_frac == 0.0
    assert m.dispatches_per_iter == 0.0
    assert m.padded_token_frac == 0.0


def test_packed_metrics_derivations():
    m = ServeMetrics(host_s=1.0, device_s=3.0, mixed_iters=4,
                     mixed_dispatches=4, packed_tokens_real=90,
                     packed_tokens_padded=100)
    assert m.host_frac == pytest.approx(0.25)
    assert m.dispatches_per_iter == 1.0
    assert m.padded_token_frac == pytest.approx(0.1)


def test_host_device_split_recorded(rng):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(9, 5), (21, 5)])
    _, m = _serve(eng, reqs)
    assert m.device_s > 0.0 and m.host_s >= 0.0
    assert 0.0 <= m.host_frac < 1.0
