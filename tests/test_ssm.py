"""Recurrent mixers: chunkwise-parallel forms must equal step-by-step
recurrence (the invariant that makes the state a valid KV-cache analogue)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.models import ssm as S


def _mk_qkv(rng, B, T, H, dh):
    q = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32) * dh ** -0.5
    v = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    i_pre = jnp.asarray(rng.normal(size=(B, T, H)), jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.asarray(rng.normal(size=(B, T, H)) + 2.0, jnp.float32))
    return q, k, v, i_pre, logf


@pytest.mark.parametrize("T", [1, 7, 128, 200])
def test_mlstm_chunked_equals_recurrent(rng, T):
    B, H, dh = 2, 2, 16
    q, k, v, i_pre, logf = _mk_qkv(rng, B, T, H, dh)
    state0 = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
              "m": jnp.zeros((B, H))}
    h_par, st_par = S.mlstm_chunked(q, k, v, i_pre, logf, state0)

    st = state0
    outs = []
    for t in range(T):
        h, st = S.mlstm_step(q[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                             i_pre[:, t:t+1], logf[:, t:t+1], st)
        outs.append(h[:, 0])
    h_rec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(st_par["C"] * jnp.exp(st_par["m"])[..., None, None]),
        np.asarray(st["C"] * jnp.exp(st["m"])[..., None, None]),
        rtol=2e-3, atol=2e-3)


def test_mlstm_prefill_then_decode_continuity(rng):
    """prefill(T) state + decode steps == full parallel over T+n."""
    B, H, dh, T, n = 1, 2, 16, 50, 5
    q, k, v, i_pre, logf = _mk_qkv(rng, B, T + n, H, dh)
    z = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
         "m": jnp.zeros((B, H))}
    h_full, _ = S.mlstm_chunked(q, k, v, i_pre, logf, z)
    _, st = S.mlstm_chunked(q[:, :T], k[:, :T], v[:, :T], i_pre[:, :T],
                            logf[:, :T], z)
    for t in range(T, T + n):
        h, st = S.mlstm_step(q[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                             i_pre[:, t:t+1], logf[:, t:t+1], st)
        np.testing.assert_allclose(np.asarray(h[:, 0]),
                                   np.asarray(h_full[:, t]),
                                   rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("T", [1, 9, 130])
def test_mamba_chunked_equals_recurrent(rng, T):
    B, H, dh, N = 2, 3, 8, 4
    xh = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    Bt = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    Ct = jnp.asarray(rng.normal(size=(B, T, N)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(B, T, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    logdec = dt * a
    h0 = jnp.zeros((B, H, dh, N))
    y_par, h_par = S._mamba_chunked(xh, Bt, Ct, dt, logdec, h0)

    h = h0
    ys = []
    for t in range(T):
        dec = jnp.exp(logdec[:, t])
        upd = jnp.einsum("bhd,bn,bh->bhdn", xh[:, t], Bt[:, t], dt[:, t])
        h = dec[..., None, None] * h + upd
        ys.append(jnp.einsum("bhdn,bn->bhd", h, Ct[:, t]))
    y_rec = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_par), np.asarray(h),
                               rtol=2e-4, atol=2e-4)


def test_slstm_scan_equals_stepping(rng, key):
    cfg = get_reduced("xlstm-125m")
    p = S.slstm_init(key, cfg)
    B, T = 2, 12
    x = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)), jnp.float32) * 0.3
    st0 = S.slstm_zero_state(cfg, B)
    y_scan, st_scan = S.slstm_apply(cfg, p, x, st0, "prefill")
    st = st0
    ys = []
    for t in range(T):
        y, st = S.slstm_apply(cfg, p, x[:, t:t+1], st, "decode")
        ys.append(y[:, 0])
    np.testing.assert_allclose(np.asarray(y_scan),
                               np.asarray(jnp.stack(ys, 1)),
                               rtol=2e-4, atol=2e-4)


def test_mlstm_no_nan_extreme_gates(rng):
    """Exponential gating must stay finite for extreme preactivations."""
    B, T, H, dh = 1, 64, 2, 8
    q, k, v, _, _ = _mk_qkv(rng, B, T, H, dh)
    i_pre = jnp.asarray(rng.normal(size=(B, T, H)) * 30, jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.asarray(rng.normal(size=(B, T, H)) * 30, jnp.float32))
    z = {"C": jnp.zeros((B, H, dh, dh)), "n": jnp.zeros((B, H, dh)),
         "m": jnp.zeros((B, H))}
    h, st = S.mlstm_chunked(q, k, v, i_pre, logf, z)
    assert bool(jnp.isfinite(h).all())
    assert bool(jnp.isfinite(st["m"]).all())
