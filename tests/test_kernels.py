"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles,
executed in interpret mode (kernel body runs in Python on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import decode_attention as DA
from repro.kernels import flash_attention as FA
from repro.kernels import ops as KOPS
from repro.kernels import ref as R
from repro.kernels import rmsnorm as RN


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype != jnp.float32 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,Hq,Hkv,D,window,cap",
    [
        (1, 128, 128, 4, 4, 64, None, None),        # MHA
        (2, 256, 256, 8, 2, 64, None, None),        # GQA 4:1
        (2, 128, 256, 4, 1, 128, None, None),       # MQA, Sq != Sk
        (1, 256, 256, 4, 2, 64, 64, None),          # sliding window
        (1, 128, 128, 2, 2, 64, None, 50.0),        # softcap (gemma2)
        (2, 128, 128, 6, 2, 32, 32, 30.0),          # window + cap
        (1, 384, 384, 4, 4, 96, None, None),        # phi3 head dim
    ])
def test_flash_attention_sweep(rng, B, Sq, Sk, Hq, Hkv, D, window, cap,
                               dtype):
    q = _rand(rng, (B, Sq, Hq, D), dtype)
    k = _rand(rng, (B, Sk, Hkv, D), dtype)
    v = _rand(rng, (B, Sk, Hkv, D), dtype)
    qp = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    assert FA.shape_supported(q, k)
    out = FA.flash_attention(q, k, v, qp, kp, window=window, scale=D ** -0.5,
                             attn_softcap=cap, interpret=True)
    ref = R.flash_attention_ref(q, k, v, qp, kp, window=window,
                                scale=D ** -0.5, attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_ragged_positions(rng):
    """Invalid (-1) key positions — ragged batches / ring caches."""
    B, S, H, D = 2, 256, 4, 64
    q = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32)
    v = _rand(rng, (B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    kp = jnp.where(pos % 5 == 2, -1, pos)
    out = FA.flash_attention(q, k, v, pos, kp, window=None, scale=D ** -0.5,
                             interpret=True)
    ref = R.flash_attention_ref(q, k, v, pos, kp, window=None,
                                scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sk,Hq,Hkv,D,Dv,window,cap",
    [
        (2, 256, 4, 4, 64, 64, None, None),
        (3, 512, 8, 2, 64, 64, None, None),
        (1, 256, 16, 4, 128, 128, 128, None),
        (2, 256, 4, 2, 64, 64, None, 50.0),
        (1, 512, 8, 8, 192, 128, None, None),        # MLA-ish Dv != D
    ])
def test_decode_attention_sweep(rng, B, Sk, Hq, Hkv, D, Dv, window, cap,
                                dtype):
    q = _rand(rng, (B, 1, Hq, D), dtype)
    k = _rand(rng, (B, Sk, Hkv, D), dtype)
    v = _rand(rng, (B, Sk, Hkv, Dv), dtype)
    kp = jnp.broadcast_to(jnp.arange(Sk)[None], (B, Sk))
    kp = jnp.where(kp % 9 == 5, -1, kp)               # holes (ring dump)
    qp = jnp.asarray(np.stack([np.full(1, Sk - 1 - 7 * b) for b in range(B)]),
                     jnp.int32)
    assert DA.shape_supported(q, k)
    out = DA.decode_attention(q, k, v, kp, qp, window=window,
                              scale=D ** -0.5, attn_softcap=cap,
                              interpret=True)
    ref = R.decode_attention_ref(q, k, v, kp, qp, window=window,
                                 scale=D ** -0.5, attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,P,page,npages,Hq,Hkv,D,Dv,window,cap",
    [
        (2, 9, 16, 4, 4, 4, 64, 64, None, None),      # MHA
        (3, 13, 32, 3, 8, 2, 64, 64, None, None),     # GQA 4:1
        (2, 9, 16, 4, 16, 4, 128, 128, 24, None),     # GQA + window
        (2, 9, 16, 4, 4, 2, 64, 64, None, 50.0),      # softcap (gemma2)
        (1, 7, 16, 4, 6, 2, 32, 32, 20, 30.0),        # window + cap
        (1, 9, 32, 3, 8, 8, 192, 128, None, None),    # MLA-ish Dv != D
    ])
def test_paged_decode_attention_sweep(rng, B, P, page, npages, Hq, Hkv, D,
                                      Dv, window, cap, dtype):
    """Paged kernel vs the dense-gather oracle: random block tables with
    unallocated holes, ring-style partial pages, per-slot query positions."""
    kpool = _rand(rng, (P, page, Hkv, D), dtype)
    vpool = _rand(rng, (P, page, Hkv, Dv), dtype)
    ppos = np.full((P, page), -1, np.int32)
    bt = np.full((B, npages), -1, np.int32)
    perm = rng.permutation(P - 1)           # page P-1 stays the dump page
    q_pos = np.zeros((B, 1), np.int32)
    next_page = 0
    for b in range(B):
        ctx = int(rng.integers(1, npages * page))
        q_pos[b, 0] = ctx - 1
        used = -(-ctx // page)
        bt[b, :used] = perm[next_page:next_page + used]
        next_page += used
        for t in range(ctx):
            ppos[bt[b, t // page], t % page] = t
    q = _rand(rng, (B, 1, Hq, D), dtype)
    assert DA.paged_shape_supported(q, kpool, jnp.asarray(bt))
    out = DA.paged_decode_attention(q, kpool, vpool, jnp.asarray(ppos),
                                    jnp.asarray(bt), jnp.asarray(q_pos),
                                    window=window, scale=D ** -0.5,
                                    attn_softcap=cap, interpret=True)
    ref = R.paged_decode_attention_ref(q, kpool, vpool, jnp.asarray(ppos),
                                       jnp.asarray(bt), jnp.asarray(q_pos),
                                       window=window, scale=D ** -0.5,
                                       attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
    assert np.abs(np.asarray(out, np.float32)
                  - np.asarray(ref, np.float32)).max() <= 1e-2


def test_paged_decode_matches_dense_decode(rng):
    """The paged kernel over a scattered pool == the dense decode kernel
    over the equivalent contiguous cache."""
    B, P, page, npages, H, D = 2, 9, 32, 4, 4, 64
    kpool = _rand(rng, (P, page, H, D), jnp.float32)
    vpool = _rand(rng, (P, page, H, D), jnp.float32)
    ppos = np.full((P, page), -1, np.int32)
    bt = np.asarray([[3, 0, 6, -1], [5, 2, -1, -1]], np.int32)
    q_pos = np.asarray([[100], [50]], np.int32)
    for b in range(B):
        for t in range(int(q_pos[b, 0]) + 1):
            if t // page < npages and bt[b, t // page] >= 0:
                ppos[bt[b, t // page], t % page] = t
    q = _rand(rng, (B, 1, H, D), jnp.float32)
    out = DA.paged_decode_attention(q, kpool, vpool, jnp.asarray(ppos),
                                    jnp.asarray(bt), jnp.asarray(q_pos),
                                    window=None, scale=D ** -0.5,
                                    interpret=True)
    # densify: gather pages into (B, npages*page, H, D)
    safe = np.where(bt >= 0, bt, P - 1)
    kd = jnp.asarray(np.asarray(kpool)[safe].reshape(B, npages * page, H, D))
    vd = jnp.asarray(np.asarray(vpool)[safe].reshape(B, npages * page, H, D))
    kp = np.where(bt[..., None] >= 0, np.asarray(ppos)[safe], -1)
    kp = jnp.asarray(kp.reshape(B, npages * page))
    ref = DA.decode_attention(q, kd, vd, kp, jnp.asarray(q_pos),
                              window=None, scale=D ** -0.5, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("shape", [(4, 256), (2, 64, 512), (1, 8, 128)])
def test_rmsnorm_sweep(rng, shape, dtype):
    x = _rand(rng, shape, dtype)
    w = _rand(rng, shape[-1:], jnp.float32) * 0.1
    assert RN.shape_supported(x)
    out = RN.fused_rmsnorm(x, w, interpret=True)
    ref = R.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,dh,chunk", [
    (1, 128, 2, 16, 128),
    (2, 256, 2, 32, 128),
    (1, 64, 4, 8, 64),        # single chunk
    (2, 512, 1, 64, 128),
])
def test_mlstm_chunk_kernel_sweep(rng, B, S, H, dh, chunk, dtype):
    """4th kernel: chunkwise mLSTM vs the jnp chunked oracle, incl.
    nonzero initial state (prefix continuation)."""
    import jax
    from repro.kernels.mlstm_chunk import mlstm_chunked_kernel
    from repro.models.ssm import mlstm_chunked
    q = _rand(rng, (B, S, H, dh), dtype)
    k = _rand(rng, (B, S, H, dh), dtype)
    v = _rand(rng, (B, S, H, dh), dtype)
    i_pre = jnp.asarray(rng.normal(size=(B, S, H)), jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.asarray(rng.normal(size=(B, S, H)) + 2, jnp.float32))
    st = {"C": jnp.asarray(rng.normal(size=(B, H, dh, dh)) * 0.1,
                           jnp.float32),
          "n": jnp.asarray(np.abs(rng.normal(size=(B, H, dh))),
                           jnp.float32),
          "m": jnp.zeros((B, H), jnp.float32)}
    h_ref, st_ref = mlstm_chunked(q, k, v, i_pre, logf, st)
    h_k, st_k = mlstm_chunked_kernel(q, k, v, i_pre, logf, st,
                                     chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_ref),
                               **_tol(dtype))
    np.testing.assert_allclose(np.asarray(st_k["m"]),
                               np.asarray(st_ref["m"]), rtol=1e-5,
                               atol=1e-5)


def test_kernel_mode_dispatch(rng):
    """Model attention dispatches to the Pallas kernel in interpret mode
    and produces the same result as the jnp path."""
    from repro.models import layers as L
    B, S, H, D = 1, 128, 4, 64
    q = _rand(rng, (B, S, H, D), jnp.float32)
    k = _rand(rng, (B, S, H, D), jnp.float32)
    v = _rand(rng, (B, S, H, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    off = L.mha_attention(q, k, v, pos, pos, window=None, scale=D ** -0.5)
    with KOPS.kernel_mode_ctx("interpret"):
        on = L.mha_attention(q, k, v, pos, pos, window=None, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(on), np.asarray(off),
                               rtol=2e-5, atol=2e-5)
