"""Bucket-batcher edge cases, prompt-overflow policy, and engine stats —
coverage the seed lacked (ISSUE-1 satellites)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core.engine import EngineStats, InferenceEngine
from repro.core.precision import FP32
from repro.core.scheduler import (DEFAULT_BUCKETS, Batch, DynamicBatcher,
                                  PromptOverflowError, Request, pad_batch,
                                  pick_bucket, truncate_prompt)
from repro.models import transformer as T

# ---------------------------------------------------------------------------
# DynamicBatcher
# ---------------------------------------------------------------------------


def test_empty_queue_returns_none():
    b = DynamicBatcher(max_batch=4)
    assert b.pending() == 0
    assert b.next_batch() is None


def test_oversize_batch_splits():
    b = DynamicBatcher(max_batch=3)
    for i in range(8):
        b.add(Request(uid=i, tokens=[2] * 10))
    sizes = []
    while (batch := b.next_batch()) is not None:
        sizes.append(batch.size)
        assert batch.size <= 3
    assert sum(sizes) == 8


def test_mixed_buckets_grouping():
    b = DynamicBatcher(max_batch=8)
    lens = [5, 40, 7, 100, 31, 33]
    for i, ln in enumerate(lens):
        b.add(Request(uid=i, tokens=[2] * ln))
    batches = []
    while (batch := b.next_batch()) is not None:
        batches.append(batch)
        # every request in a batch shares the batch's bucket
        for r in batch.requests:
            assert pick_bucket(r.prompt_len, DEFAULT_BUCKETS) \
                == batch.padded_len
    assert sorted(b_.padded_len for b_ in batches) == [32, 64, 128]


def test_unsorted_batcher_keeps_fifo_grouping():
    b = DynamicBatcher(max_batch=4, sort_by_length=False)
    for i, ln in enumerate([100, 5, 101]):
        b.add(Request(uid=i, tokens=[2] * ln))
    first = b.next_batch()
    assert [r.uid for r in first.requests] == [0, 2]   # head bucket = 128


# ---------------------------------------------------------------------------
# Prompt overflow policy (was: silent clamp to buckets[-1] + slice)
# ---------------------------------------------------------------------------


def test_overlong_prompt_truncates_left_with_warning():
    limit = DEFAULT_BUCKETS[-1]
    toks = list(range(limit + 50))
    b = DynamicBatcher(max_batch=2)
    with pytest.warns(UserWarning, match="exceeds the maximum"):
        b.add(Request(uid=0, tokens=toks))
    batch = b.next_batch()
    # the *last* `limit` tokens survive (recent context conditions
    # generation), not the first
    assert batch.requests[0].tokens == toks[-limit:]
    padded, lens = pad_batch(batch)
    assert padded.shape == (1, limit) and lens[0] == limit


def test_overlong_prompt_reject_mode():
    b = DynamicBatcher(max_batch=2, overflow="reject")
    with pytest.raises(PromptOverflowError):
        b.add(Request(uid=0, tokens=[2] * (DEFAULT_BUCKETS[-1] + 1)))


def test_pad_batch_refuses_silent_clip():
    batch = Batch(requests=[Request(uid=0, tokens=[2] * 40)], padded_len=32)
    with pytest.raises(PromptOverflowError):
        pad_batch(batch)


def test_truncate_prompt_noop_within_limit():
    toks = [1, 2, 3]
    assert truncate_prompt(toks, 8) is toks


def test_serve_bounds_buckets_to_engine_context(rng):
    """engine.serve must never prefill wider than its own max_len: a
    prompt that fits a DEFAULT bucket but not the engine context is
    truncated (loudly), not silently scattered past the cache."""
    cfg = get_reduced("unimo-text")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    assert eng.prompt_buckets() == (32, 64)
    toks = [2] + list(map(int, rng.integers(4, 800, size=100)))
    with pytest.warns(UserWarning, match="exceeds the maximum"):
        done = eng.serve([Request(uid=0, tokens=toks, max_new_tokens=4)])
    assert done[0].tokens == toks[-64:]
    assert done[0].result is not None


# ---------------------------------------------------------------------------
# EngineStats
# ---------------------------------------------------------------------------


def test_engine_stats_merge_sums_every_field():
    a = EngineStats(prefill_s=1.0, decode_s=2.0, nocache_s=0.5,
                    prompt_tokens=10, generated_tokens=20, batches=1)
    b = EngineStats(prefill_s=0.25, decode_s=0.75, nocache_s=1.5,
                    prompt_tokens=5, generated_tokens=2, batches=3)
    a.merge(b)
    assert a == EngineStats(prefill_s=1.25, decode_s=2.75, nocache_s=2.0,
                            prompt_tokens=15, generated_tokens=22, batches=4)


# ---------------------------------------------------------------------------
# EOS at the first sampled token (engine KV path)
# ---------------------------------------------------------------------------


def test_generate_kv_eos_first_token(monkeypatch):
    """If the very first sampled token is EOS, the row emits nothing and
    the fused greedy loop must not resurrect it."""
    import repro.core.engine as E
    from repro.core.tokenizer import EOS
    monkeypatch.setattr(
        E, "sample",
        lambda logits, rng_, sp: jnp.full(logits.shape[:-1], EOS, jnp.int32))
    cfg = get_reduced("unimo-text")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64)
    toks = np.asarray([[2, 5, 9, 11], [2, 7, 0, 0]], np.int32)
    out = eng.generate_batch(toks, np.asarray([4, 2], np.int32), 6)
    assert (out == -1).all()
    assert eng.stats.generated_tokens == 0
