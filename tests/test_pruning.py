"""Embedding pruning (paper P2): exact-logit invariance + map properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_reduced
from repro.core import pruning as PR
from repro.core.precision import FP32
from repro.models import transformer as T

settings.register_profile("prune", deadline=None, max_examples=15)
settings.load_profile("prune")


@pytest.mark.parametrize("arch", ["unimo-text", "phi3-mini-3.8b"])
def test_kept_token_logits_invariant(arch, rng, key):
    """Pruned model's logits == unpruned logits at kept vocab entries,
    for prompts made of kept tokens (tied and untied heads)."""
    cfg = get_reduced(arch)
    params = T.init_params(key, cfg)
    freqs = {i: 1000 - i for i in range(300)}
    p2, cfg2, maps = PR.prune_model(params, cfg, freqs, max_vocab=128)
    assert cfg2.vocab_size == maps.new_vocab

    toks = jnp.asarray(rng.choice(maps.keep_ids[:100], size=(2, 8)),
                       jnp.int32)
    lg1, _ = T.forward_train(params, cfg, toks, policy=FP32, remat=False)
    lg2, _ = T.forward_train(p2, cfg2,
                             jnp.asarray(PR.remap_tokens(np.asarray(toks),
                                                         maps)),
                             policy=FP32, remat=False)
    np.testing.assert_allclose(
        np.asarray(lg1)[:, :, maps.keep_ids], np.asarray(lg2),
        rtol=1e-5, atol=1e-5)


def test_position_trim_invariance(rng, key):
    """The paper's 512->128 trim: outputs identical for seqs <= 128."""
    cfg = get_reduced("unimo-text")
    params = T.init_params(key, cfg)
    p2, cfg2 = PR.trim_positions(params, cfg, 32)
    assert p2["embed"]["pos"].shape[0] == 32
    toks = jnp.asarray(rng.integers(4, cfg.vocab_size, size=(2, 16)),
                       jnp.int32)
    lg1, _ = T.forward_train(params, cfg, toks, policy=FP32, remat=False)
    lg2, _ = T.forward_train(p2, cfg2, toks, policy=FP32, remat=False)
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                               rtol=1e-6, atol=1e-6)


def test_trim_positions_noop_for_rope(key):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(key, cfg)
    p2, cfg2 = PR.trim_positions(params, cfg, 32)
    assert cfg2.max_seq_len == cfg.max_seq_len       # documented no-op


@given(st.integers(0, 2 ** 31), st.integers(8, 64))
def test_map_roundtrip(seed, keep_n):
    rng = np.random.default_rng(seed)
    V = 256
    freqs = {int(i): int(c) for i, c in
             enumerate(rng.integers(0, 1000, size=V))}
    keep = PR.select_keep_ids(freqs, V, max_vocab=keep_n)
    maps = PR.build_maps(keep, V)
    assert len(maps.keep_ids) >= 4                  # specials always kept
    # roundtrip over kept ids
    kept = maps.keep_ids
    round1 = PR.unmap_tokens(PR.remap_tokens(kept, maps), maps)
    np.testing.assert_array_equal(round1, kept)
    # non-kept ids map to UNK's new id
    dropped = np.setdiff1d(np.arange(V), kept)
    if len(dropped):
        unk_new = maps.old_to_new[1]
        assert (maps.old_to_new[dropped] == unk_new).all()


@given(st.integers(0, 2 ** 31), st.floats(0.1, 0.999))
def test_coverage_selection(seed, coverage):
    rng = np.random.default_rng(seed)
    V = 128
    counts = rng.zipf(1.5, size=V).astype(np.int64)
    freqs = {int(i): int(c) for i, c in enumerate(counts)}
    keep = PR.select_keep_ids(freqs, V, coverage=coverage)
    kept_mass = counts[keep].sum() / counts.sum()
    assert kept_mass >= coverage - 1e-9


def test_engine_pruned_equivalence(rng, key):
    """Engine with a pruned model produces the same generations (greedy)
    when the pruned vocab covers the sampled tokens."""
    from repro.core.engine import InferenceEngine
    cfg = get_reduced("unimo-text")
    params = T.init_params(key, cfg)
    # keep ~everything that matters: top 1500 of 1600
    freqs = {i: 10_000 - i for i in range(cfg.vocab_size)}
    p2, cfg2, maps = PR.prune_model(params, cfg, freqs,
                                    max_vocab=cfg.vocab_size - 50)
    toks = np.asarray(rng.integers(4, 1000, size=(2, 8)), np.int32)
    lens = np.array([8, 5], np.int32)
    e1 = InferenceEngine(cfg, params, policy=FP32, max_len=48)
    e2 = InferenceEngine(cfg2, p2, policy=FP32, max_len=48, prune_maps=maps)
    g1 = e1.generate_batch(toks.copy(), lens.copy(), 6)
    g2 = e2.generate_batch(toks.copy(), lens.copy(), 6)
    keep = set(int(i) for i in maps.keep_ids)
    if all(int(t) in keep for t in g1[g1 >= 0]):
        np.testing.assert_array_equal(g1, g2)


def test_serve_continuous_pruned_parity(rng, key):
    """Serve-time vocab pruning on the paged continuous path: prompts
    are remapped at admission and results unmapped at emit, so greedy
    token streams match the unpruned engine verbatim whenever prompts
    and generations stay inside the kept vocab (exact-logit invariance
    at kept entries + token-id-independent serving machinery)."""
    import copy
    from repro.core.engine import InferenceEngine
    from repro.core.scheduler import Request
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(key, cfg)
    # keep the 448 most frequent of 512 ids; prompts sample 4..400, so
    # every prompt token survives the prune
    freqs = {i: 10_000 - i for i in range(cfg.vocab_size)}
    p2, cfg2, maps = PR.prune_model(params, cfg, freqs,
                                    max_vocab=cfg.vocab_size - 64)
    reqs = [Request(uid=i, tokens=[2] + list(map(int, rng.integers(
                        4, 400, size=ln))), max_new_tokens=mn)
            for i, (ln, mn) in enumerate([(21, 5), (9, 4), (30, 5)])]
    e1 = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    e2 = InferenceEngine(cfg2, p2, policy=FP32, max_len=64, max_batch=2,
                         prune_maps=maps)
    base, _ = e1.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                  max_batched_tokens=16,
                                  chunked_prefill=True)
    done, _ = e2.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                  max_batched_tokens=16,
                                  chunked_prefill=True)
    outs1 = {r.uid: r.result for r in base}
    outs2 = {r.uid: r.result for r in done}
    keep = set(int(i) for i in maps.keep_ids)
    compared = 0
    for uid, out in outs1.items():
        if all(int(t) in keep for t in out):
            assert outs2[uid] == out
            compared += 1
    assert compared > 0                   # parity actually exercised
