"""Structural assertions: every assigned config matches the brief exactly."""
import pytest

from repro.configs.base import MOE_FFN
from repro.configs.registry import ASSIGNED, get_config, get_reduced

EXPECT = {
    "qwen3-4b": dict(L=36, d=2560, H=32, kv=8, ff=9728, V=151936),
    "hymba-1.5b": dict(L=32, d=1600, H=25, kv=5, ff=5504, V=32001),
    "musicgen-medium": dict(L=48, d=1536, H=24, kv=24, ff=6144, V=2048),
    "deepseek-v3-671b": dict(L=61, d=7168, H=128, kv=128, V=129280),
    "gemma3-27b": dict(L=62, d=5376, H=32, kv=16, ff=21504, V=262144),
    "xlstm-125m": dict(L=12, d=768, H=4, kv=4, V=50304),
    "phi3-mini-3.8b": dict(L=32, d=3072, H=32, kv=32, ff=8192, V=32064),
    "internvl2-1b": dict(L=24, d=896, H=14, kv=2, ff=4864, V=151655),
    "qwen3-moe-235b-a22b": dict(L=94, d=4096, H=64, kv=4, V=151936),
    "gemma2-2b": dict(L=26, d=2304, H=8, kv=4, ff=9216, V=256000),
}


@pytest.mark.parametrize("arch", list(EXPECT))
def test_exact_config(arch):
    cfg = get_config(arch)
    e = EXPECT[arch]
    assert cfg.num_layers == e["L"]
    assert cfg.d_model == e["d"]
    assert cfg.num_heads == e["H"]
    assert cfg.num_kv_heads == e["kv"]
    assert cfg.vocab_size == e["V"]
    if "ff" in e:
        assert cfg.d_ff == e["ff"]
    assert cfg.source


def test_assigned_count():
    assert len(ASSIGNED) == 10


def test_moe_configs():
    ds = get_config("deepseek-v3-671b")
    assert ds.moe.num_experts == 256 and ds.moe.top_k == 8
    assert ds.moe.num_shared_experts == 1 and ds.moe.d_ff_expert == 2048
    assert ds.mla is not None and ds.mtp
    qw = get_config("qwen3-moe-235b-a22b")
    assert qw.moe.num_experts == 128 and qw.moe.top_k == 8
    assert all(s.pattern[0].ffn == MOE_FFN for s in qw.stacks)


def test_param_counts_scale():
    """Total parameter counts should land near the model names."""
    ds = get_config("deepseek-v3-671b").param_counts()
    assert 5.5e11 < ds["total"] < 8e11, ds["total"]
    assert 2e10 < ds["active"] < 4.5e10, ds["active"]
    qw = get_config("qwen3-moe-235b-a22b").param_counts()
    assert 1.7e11 < qw["total"] < 3e11, qw["total"]
    g3 = get_config("gemma3-27b").param_counts()
    assert 2.0e10 < g3["total"] < 3.5e10, g3["total"]
    x = get_config("xlstm-125m").param_counts()
    assert 0.7e8 < x["total"] < 3e8, x["total"]


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_bounds(arch):
    """Reduced smoke variants respect the brief: <=2-ish layers,
    d_model<=512, <=4 experts."""
    r = get_reduced(arch)
    assert r.num_layers <= 2
    assert r.d_model <= 512
    if r.moe:
        assert r.moe.num_experts <= 4


def test_gemma_patterns():
    g3 = get_config("gemma3-27b")
    first = g3.stacks[0].pattern
    assert len(first) == 6
    assert [s.window for s in first] == [1024] * 5 + [None]
    g2 = get_config("gemma2-2b")
    assert [s.window for s in g2.stacks[0].pattern] == [4096, None]
    assert g2.attn_softcap == 50.0 and g2.final_softcap == 30.0
