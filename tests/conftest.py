import os

# Tests run on the single real CPU device (the dry-run subprocesses force
# their own placeholder device count; never set it here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)
