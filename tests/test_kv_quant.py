"""Int8 quantized KV-cache pages (PR-3 tentpole).

Contracts under test:
  * quantize/dequantize round-trip error is bounded by the per-entry
    absmax scale (half a quantization step per element);
  * paged pool construction honors the ``kv_dtype`` policy axis per
    layer family — attention layers get int8 pools + scale pools, the
    dense-state families (MLA / recurrent / hybrid) keep full precision;
  * write -> gather round-trips through the quantized pool stay within
    the quantization error bound, for prefill scatter and decode scatter
    alike;
  * the fused-dequant paged Pallas decode kernel (interpret mode)
    matches the dense-gather fp32 oracle;
  * COW page copies carry the scale pools with the K/V codes;
  * serve_continuous on an int8 pool: shared-prefix serving is
    bit-identical to unshared serving (per-entry quantization is
    deterministic per token row, so who wrote a page cannot matter);
  * ServeMetrics capacity counters report the pool geometry and the
    zero-token-trace guards hold.
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ATTN, HYBRID, MLA, MLSTM
from repro.configs.registry import get_reduced
from repro.core import kv_cache as KV
from repro.core.continuous import ServeMetrics
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32, kv_store_dtype
from repro.core.scheduler import Request
from repro.kernels import decode_attention as DA
from repro.kernels import ref as R
from repro.models import transformer as T

INT8 = dataclasses.replace(FP32, kv_dtype="int8")


# ---------------------------------------------------------------------------
# Quantize / dequantize primitives
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 2, 16), (3, 8, 4, 64), (1, 1, 128)])
def test_quant_roundtrip_error_bound(rng, shape):
    """|dequant(quant(x)) - x| <= absmax(row)/127/2 per element (half a
    quantization step at the row's scale)."""
    x = jnp.asarray(rng.normal(size=shape) * 3.0, jnp.float32)
    q, s = KV.quantize_kv(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == shape[:-1]
    back = KV.dequantize_kv(q, s)
    bound = np.abs(np.asarray(x)).max(axis=-1, keepdims=True) / 127.0 / 2.0
    assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound + 1e-7).all()


def test_quant_zero_rows_and_determinism(rng):
    z = jnp.zeros((2, 3, 8), jnp.float32)
    q, s = KV.quantize_kv(z)
    assert (np.asarray(q) == 0).all() and (np.asarray(s) == 0).all()
    assert (np.asarray(KV.dequantize_kv(q, s)) == 0).all()
    # identical rows quantize identically regardless of batch context —
    # the property shared-prefix bit-exactness rests on
    x = jnp.asarray(rng.normal(size=(5, 2, 16)), jnp.float32)
    q1, s1 = KV.quantize_kv(x)
    q2, s2 = KV.quantize_kv(jnp.concatenate([x, x * 7.0], axis=0))
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2)[:5])
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2)[:5])


# ---------------------------------------------------------------------------
# Pool construction per layer family / policy axis
# ---------------------------------------------------------------------------


def test_kv_store_dtype_resolution():
    assert kv_store_dtype("auto", jnp.float32) == jnp.float32
    assert kv_store_dtype("bf16", jnp.float32) == jnp.bfloat16
    assert kv_store_dtype("fp16", jnp.float32) == jnp.float16
    assert kv_store_dtype("int8", jnp.float32) == jnp.int8
    assert kv_store_dtype("int8", jnp.float32,
                          allow_int8=False) == jnp.float32
    with pytest.raises(ValueError):
        kv_store_dtype("fp8", jnp.float32)


@pytest.mark.parametrize("arch,family", [
    ("qwen3-4b", ATTN), ("deepseek-v3-671b", MLA),
    ("xlstm-125m", MLSTM), ("hymba-1.5b", HYBRID)])
def test_paged_pool_dtypes_per_family(arch, family):
    """int8 applies to pure-attention pools only; MLA / recurrent /
    hybrid keep dense (or pool) full-precision state — the same opt-out
    families as prefix sharing."""
    cfg = get_reduced(arch)
    cache = T.init_paged_cache(cfg, num_pages=4, page_size=8, max_slots=2,
                               max_len=32, dtype=jnp.float32,
                               kv_dtype="int8")
    for stack_c, stack in zip(cache["layers"], cfg.stacks):
        for c, spec in zip(stack_c, stack.pattern):
            if spec.mixer == ATTN:
                assert c["pk"].dtype == jnp.int8
                assert c["pk_scale"].dtype == jnp.float32
                assert c["pk_scale"].shape == c["pk"].shape[:-1]
                assert c["pv_scale"].shape == c["pv"].shape[:-1]
            elif spec.mixer == HYBRID:
                assert c["pk"].dtype == jnp.float32      # opt-out
                assert "pk_scale" not in c
            else:
                assert "pk" not in c and "pk_scale" not in c


def test_paged_pool_bytes_halves_under_int8():
    cfg = get_reduced("qwen3-4b")
    kw = dict(num_pages=8, page_size=8, max_slots=2, max_len=32,
              dtype=jnp.bfloat16)
    full = KV.paged_pool_bytes(T.init_paged_cache(cfg, **kw))
    quant = KV.paged_pool_bytes(
        T.init_paged_cache(cfg, kv_dtype="int8", **kw))
    # int8 codes are half the bf16 bytes; scales + ppos add back a little
    assert quant < full
    D = cfg.resolved_head_dim
    assert quant < full * (0.5 + 4.0 / (2 * D) + 0.25)


# ---------------------------------------------------------------------------
# Write / gather round-trip on a quantized pool
# ---------------------------------------------------------------------------


def _int8_pool(P, page, H, D):
    return {"pk": jnp.zeros((P, page, H, D), jnp.int8),
            "pv": jnp.zeros((P, page, H, D), jnp.int8),
            "pk_scale": jnp.zeros((P, page, H), jnp.float32),
            "pv_scale": jnp.zeros((P, page, H), jnp.float32),
            "ppos": jnp.full((P, page), -1, jnp.int32)}


def test_paged_write_gather_roundtrip_int8(rng):
    P, page, H, D = 6, 8, 2, 16
    pool = _int8_pool(P, page, H, D)
    bt = jnp.asarray([[0, 3, -1, -1]], jnp.int32)
    S = 11
    k = jnp.asarray(rng.normal(size=(1, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, H, D)), jnp.float32)
    cache_pos = jnp.asarray([[0, 1, 2, 3, 4, 5, 6, 7, 8, -1, -1]], jnp.int32)
    ring = KV.paged_ring_len(None, page, 4)
    pool = KV.paged_write_prefill(pool, {"k": k, "v": v}, cache_pos, bt,
                                  ring_len=ring)
    for t in range(9, 11):
        pool = KV.paged_write_decode(
            pool, {"k": k[:, t:t + 1], "v": v[:, t:t + 1]},
            jnp.asarray([t], jnp.int32), bt, jnp.asarray([True]),
            ring_len=ring)
    kk, vv, kp = KV.paged_gather(pool, bt)
    np.testing.assert_array_equal(np.asarray(kp[0, :11]), np.arange(11))
    for got, want in ((kk, k), (vv, v)):
        bound = np.abs(np.asarray(want[0])).max(axis=-1,
                                                keepdims=True) / 254.0
        err = np.abs(np.asarray(got[0, :11]) - np.asarray(want[0]))
        assert (err <= bound + 1e-7).all()


def test_copy_pages_carries_scales(rng):
    """A COW clone must copy scale rows with the int8 codes — otherwise
    the private tail page dequantizes with the wrong magnitudes."""
    P, page, H, D = 4, 4, 2, 8
    pool = _int8_pool(P, page, H, D)
    k = jnp.asarray(rng.normal(size=(1, page, H, D)) * 5.0, jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, page, H, D)), jnp.float32)
    bt = jnp.asarray([[0]], jnp.int32)
    cache_pos = jnp.asarray([[4, 5, 6, 7]], jnp.int32)
    pool = KV.paged_write_prefill(pool, {"k": k, "v": v}, cache_pos, bt,
                                  ring_len=page)
    out = KV.copy_pages(pool, jnp.asarray([0]), jnp.asarray([2]),
                        jnp.asarray([6]))
    np.testing.assert_array_equal(np.asarray(out["ppos"][2]),
                                  [4, 5, -1, -1])
    for key in ("pk", "pv", "pk_scale", "pv_scale"):
        np.testing.assert_array_equal(np.asarray(out[key][2]),
                                      np.asarray(pool[key][0]))
        # source page untouched (copy, not move)
        np.testing.assert_array_equal(np.asarray(out[key][0]),
                                      np.asarray(pool[key][0]))
    # scan-repeats layout variant
    pool_r = {kk_: jnp.tile(vv_[None], (3,) + (1,) * vv_.ndim)
              for kk_, vv_ in pool.items()}
    out_r = KV.copy_pages(pool_r, jnp.asarray([0]), jnp.asarray([2]),
                          jnp.asarray([6]))
    for key in ("pk_scale", "pv_scale"):
        np.testing.assert_array_equal(np.asarray(out_r[key][:, 2]),
                                      np.asarray(pool_r[key][:, 0]))


# ---------------------------------------------------------------------------
# Fused-dequant Pallas kernel vs the fp32 oracle (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,P,page,npages,Hq,Hkv,D,Dv,window,cap",
    [
        (2, 9, 16, 4, 4, 4, 64, 64, None, None),      # MHA
        (3, 13, 32, 3, 8, 2, 64, 64, None, None),     # GQA 4:1
        (2, 9, 16, 4, 16, 4, 128, 128, 24, None),     # GQA + window
        (2, 9, 16, 4, 4, 2, 64, 64, None, 50.0),      # softcap (gemma2)
        (1, 7, 16, 4, 6, 2, 32, 32, 20, 30.0),        # window + cap
    ])
def test_paged_decode_q8_kernel_vs_oracle(rng, B, P, page, npages, Hq, Hkv,
                                          D, Dv, window, cap):
    """int8 pools with random block tables / holes / per-slot context
    lengths: the fused-dequant kernel must match the dense-gather
    dequantizing oracle to fp32 online-softmax tolerance."""
    kq, ks = KV.quantize_kv(
        jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32))
    vq, vs = KV.quantize_kv(
        jnp.asarray(rng.normal(size=(P, page, Hkv, Dv)), jnp.float32))
    ppos = np.full((P, page), -1, np.int32)
    bt = np.full((B, npages), -1, np.int32)
    perm = rng.permutation(P - 1)           # page P-1 stays the dump page
    q_pos = np.zeros((B, 1), np.int32)
    next_page = 0
    for b in range(B):
        ctx = int(rng.integers(1, npages * page))
        q_pos[b, 0] = ctx - 1
        used = -(-ctx // page)
        bt[b, :used] = perm[next_page:next_page + used]
        next_page += used
        for t in range(ctx):
            ppos[bt[b, t // page], t % page] = t
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, D)), jnp.float32)
    assert DA.paged_shape_supported(q, kq, jnp.asarray(bt))
    out = DA.paged_decode_attention_q8(
        q, kq, ks, vq, vs, jnp.asarray(ppos), jnp.asarray(bt),
        jnp.asarray(q_pos), window=window, scale=D ** -0.5,
        attn_softcap=cap, interpret=True)
    ref = R.paged_decode_attention_ref(
        q, kq, vq, jnp.asarray(ppos), jnp.asarray(bt), jnp.asarray(q_pos),
        window=window, scale=D ** -0.5, attn_softcap=cap,
        k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_q8_kernel_dispatches_through_model(rng):
    """serve_continuous on an int8 pool with kernel mode on: the fused
    int8 kernel path must produce the same greedy outputs as the jnp
    dequant-gather fallback."""
    from repro.kernels import ops as KOPS
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = [Request(uid=i, tokens=[2] + list(map(int, rng.integers(
        4, 400, size=ln))), max_new_tokens=mn)
        for i, (ln, mn) in enumerate([(5, 4), (9, 4), (14, 4)])]
    eng = InferenceEngine(cfg, params, policy=INT8, max_len=64, max_batch=3)
    base, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   steps_per_sync=2, prefix_cache=False)
    eng2 = InferenceEngine(cfg, params, policy=INT8, max_len=64, max_batch=3)
    with KOPS.kernel_mode_ctx("interpret"):
        done, _ = eng2.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                        steps_per_sync=2,
                                        prefix_cache=False)
    for a, b in zip(base, done):
        assert a.result == b.result


# ---------------------------------------------------------------------------
# Serving: shared-prefix int8 == unshared int8, bit-exact
# ---------------------------------------------------------------------------


def test_int8_shared_prefix_bit_identical_to_unshared(rng):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prefix = list(map(int, rng.integers(4, 400, size=21)))
    reqs = []
    for i, (ln, mn) in enumerate([(5, 5), (3, 4), (7, 5), (4, 4), (6, 5)]):
        body = list(map(int, rng.integers(4, 400, size=ln)))
        reqs.append(Request(uid=i, tokens=[2] + prefix + body,
                            max_new_tokens=mn))
    eng_off = InferenceEngine(cfg, params, policy=INT8, max_len=64,
                              max_batch=2)
    off, m_off = eng_off.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                          steps_per_sync=3,
                                          prefix_cache=False)
    eng_on = InferenceEngine(cfg, params, policy=INT8, max_len=64,
                             max_batch=2)
    on, m_on = eng_on.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                       steps_per_sync=3, prefix_cache=True)
    for a, b in zip(off, on):
        assert a.result == b.result, f"uid {a.uid}"
        assert a.result            # non-empty: the pool actually decoded
    assert m_on.prefix_matched_tokens > 0 and m_on.pages_shared > 0
    assert m_on.cow_copies > 0          # partial tail pages were COW'd
    assert m_off.kv_dtype == "int8"
    # int8 pool reports fewer bytes per token than the fp32 pool
    eng_fp = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                             max_batch=2)
    _, m_fp = eng_fp.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                      prefix_cache=False)
    assert m_off.kv_bytes_per_token < 0.5 * m_fp.kv_bytes_per_token
    assert 0 < m_off.kv_pool_bytes < m_fp.kv_pool_bytes
    assert m_off.peak_pages_in_use > 0


def test_int8_serving_stays_close_to_fp(rng):
    """Quantization noise must not derail generation: int8 greedy outputs
    agree with the fp32 path on a small smoke trace (observed logit
    perturbations are ~1e-2 at unit-variance K/V; see README)."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = [Request(uid=i, tokens=[2] + list(map(int, rng.integers(
        4, 400, size=ln))), max_new_tokens=mn)
        for i, (ln, mn) in enumerate([(6, 4), (12, 4)])]
    fp, _ = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                            max_batch=2).serve_continuous(
        copy.deepcopy(reqs), page_size=8, prefix_cache=False)
    q8, _ = InferenceEngine(cfg, params, policy=INT8, max_len=64,
                            max_batch=2).serve_continuous(
        copy.deepcopy(reqs), page_size=8, prefix_cache=False)
    match = sum(a.result == b.result for a, b in zip(fp, q8))
    assert match == len(reqs)


# ---------------------------------------------------------------------------
# Metrics guards
# ---------------------------------------------------------------------------


def test_serve_metrics_zero_token_guards():
    m = ServeMetrics()
    assert m.prefill_pad_frac == 0.0
    assert m.decode_idle_frac == 0.0
    assert m.prefix_hit_rate == 0.0
    assert m.percentile_latency(50) == 0.0
    assert m.kv_pool_bytes == 0 and m.kv_bytes_per_token == 0.0
