"""Dry-run path integration: lower+compile on a small forced-device mesh
in a subprocess (so the test session's device count stays 1), plus the
roofline readers over real artifacts."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import json
import numpy as np
import jax
from jax.sharding import Mesh

import repro.launch.specs as SP
import repro.launch.hlo_analysis as HA
from repro.configs.registry import get_reduced
from repro.sharding import partition as SH

# shrink the input shapes to smoke scale
SP.INPUT_SHAPES = {
    "train_4k": {"kind": "train", "seq": 64, "batch": 8},
    "decode_32k": {"kind": "decode", "seq": 512, "batch": 8},
}

mesh = Mesh(np.asarray(jax.devices()).reshape(4, 4), ("data", "model"))
SH.set_current_mesh(mesh)
out = {}
for arch in ["qwen3-4b", "deepseek-v3-671b", "xlstm-125m"]:
    cfg = get_reduced(arch).replace(vocab_size=512)
    for shape in ["train_4k", "decode_32k"]:
        t = SP.make_target(cfg, shape, mesh)
        with mesh:
            comp = jax.jit(t.fn, donate_argnums=t.donate_argnums).lower(
                *t.args).compile()
        ha = HA.analyze(comp.as_text())
        out[f"{arch}|{shape}"] = {
            "flops": ha["flops"], "bytes": ha["bytes"],
            "coll": ha["collectives"]["total_bytes"]}
print(json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_small_mesh_all_families():
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"),
               REPRO_PERF_OPTS="")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert len(out) == 6
    for key, v in out.items():
        assert v["flops"] > 0, key
        assert v["bytes"] > 0, key
        # sharded graphs must actually communicate
        if "train" in key:
            assert v["coll"] > 0, key


def test_roofline_reader_on_artifacts():
    from benchmarks import roofline
    recs = roofline.load_records(
        os.path.join(ROOT, "experiments", "dryrun"), mesh=None)
    if not recs:
        pytest.skip("no dry-run artifacts present")
    rows = roofline.table(recs)
    assert rows, "no analyzable records"
    for t in rows:
        assert t["dominant"] in ("compute", "memory", "collective")
        assert t["compute_s"] >= 0 and t["memory_s"] > 0


def test_report_sections():
    from benchmarks import report
    recs_dir = os.path.join(ROOT, "experiments", "dryrun")
    if not os.path.isdir(recs_dir) or not os.listdir(recs_dir):
        pytest.skip("no artifacts")
    md = report.roofline_section()
    assert "| arch |" in md and "dominant" in md.lower()
