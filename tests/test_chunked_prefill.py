"""Unified token-budget scheduler + chunked prefill.

Contracts under test:

  * greedy outputs are bit-identical between the unified scheduler
    (any chunk budget — page-aligned or not) and the bucketed
    whole-prompt engine, including int8 pools, shared prefixes and
    speculative decoding;
  * the per-iteration token budget is never exceeded, decode always
    rides first, and no admitting slot starves (FCFS chunk ordering);
  * the variable-length mixed paged-attention entry matches its oracle
    under ragged per-slot query counts, and padding queries (q_pos -1)
    come back as zeros;
  * TTFT / inter-token-latency percentiles are recorded, zero-guarded
    like the other derived metrics.
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core.continuous import (ContinuousScheduler, PageAllocator,
                                   ServeMetrics)
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.scheduler import Request
from repro.kernels import decode_attention as DA
from repro.kernels import ops as KOPS
from repro.kernels import ref as R
from repro.models import transformer as T

INT8 = dataclasses.replace(FP32, kv_dtype="int8")


def _requests(rng, cfg, lens_new, prefix=None):
    prefix = prefix or []
    return [Request(uid=i,
                    tokens=[2] + prefix + list(map(int, rng.integers(
                        4, min(cfg.vocab_size, 400), size=ln))),
                    max_new_tokens=mn)
            for i, (ln, mn) in enumerate(lens_new)]


def _serve(eng, reqs, **kw):
    done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8, **kw)
    return {r.uid: r.result for r in done}, m


# ---------------------------------------------------------------------------
# Greedy parity: unified scheduler == bucketed whole-prompt engine
# ---------------------------------------------------------------------------


# chunk budgets: tiny (many chunks per prompt), large (one chunk), and
# unaligned-to-page (page_size=8; chunk boundaries fall mid-page)
@pytest.mark.parametrize("budget", [16, 64, 20])
def test_chunked_parity_sweep(rng, budget):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    shapes = [(30, 5), (40, 4), (9, 5), (22, 4), (3, 5)]
    reqs = _requests(rng, cfg, shapes)

    eng_off = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                              max_batch=3)
    base, m_off = _serve(eng_off, reqs, chunked_prefill=False)
    assert m_off.scheduler == "bucketed" and m_off.max_batched_tokens == 0

    eng_on = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                             max_batch=3)
    done, m_on = _serve(eng_on, reqs, max_batched_tokens=budget,
                        chunked_prefill=True)
    for uid, out in done.items():
        assert out == base[uid], f"budget {budget} uid {uid}"
    assert m_on.scheduler == "unified"
    assert m_on.max_batched_tokens == budget
    # every prompt token was either chunk-prefilled exactly once or
    # served from the (default-on) radix prefix cache
    assert m_on.prefill_tokens + m_on.prefix_matched_tokens \
        == sum(r.prompt_len for r in reqs)
    assert m_on.prefill_chunks >= len(reqs)
    if budget == 16:
        # 30- and 40-token prompts cannot fit one 16-token iteration
        assert m_on.prefill_chunks > len(reqs)


def test_chunked_parity_int8_pool(rng):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, cfg, [(26, 5), (11, 4), (33, 5)])
    base, _ = _serve(InferenceEngine(cfg, params, policy=INT8, max_len=64,
                                     max_batch=2),
                     reqs, chunked_prefill=False, prefix_cache=False)
    done, m = _serve(InferenceEngine(cfg, params, policy=INT8, max_len=64,
                                     max_batch=2),
                     reqs, max_batched_tokens=16, chunked_prefill=True,
                     prefix_cache=False)
    assert m.kv_dtype == "int8" and m.scheduler == "unified"
    for uid, out in done.items():
        assert out == base[uid]
        assert out                      # the quantized pool really decoded


def test_chunked_parity_shared_prefix(rng):
    """Chunked + radix sharing: chunks prefill only the unmatched
    suffix, COW still fires, and outputs stay bit-identical to both the
    unchunked run and the sharing-off chunked run."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prefix = list(map(int, rng.integers(4, 400, size=21)))
    shapes = [(5, 5), (3, 4), (7, 5), (4, 4), (6, 5)]
    reqs = _requests(rng, cfg, shapes, prefix=prefix)

    base, _ = _serve(InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                     max_batch=2),
                     reqs, chunked_prefill=False, prefix_cache=True)
    unshared, _ = _serve(InferenceEngine(cfg, params, policy=FP32,
                                         max_len=64, max_batch=2),
                         reqs, max_batched_tokens=16, chunked_prefill=True,
                         prefix_cache=False)
    done, m = _serve(InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                     max_batch=2),
                     reqs, max_batched_tokens=16, chunked_prefill=True,
                     prefix_cache=True)
    for uid, out in done.items():
        assert out == base[uid] == unshared[uid]
    assert m.prefix_matched_tokens > 0 and m.pages_shared > 0
    assert m.cow_copies > 0
    # chunks covered exactly the unmatched suffixes
    total_prompt = sum(r.prompt_len for r in reqs)
    assert m.prefill_tokens + m.prefix_matched_tokens == total_prompt


def test_chunked_parity_speculative(rng):
    """Speculation composes with the unified scheduler: decode-only
    iterations run the k+1-token verify step, so the budget floor is
    slots * (k+1) (the largest iteration must fit); iterations carrying
    prefill chunks pause drafting and charge one decode token per
    slot.  Greedy streams stay bit-identical to every other mode."""
    from repro.core.speculative import SpecConfig
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, cfg, [(24, 6), (9, 6), (31, 5)])
    base, _ = _serve(InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                     max_batch=2),
                     reqs, chunked_prefill=False)
    done, m = _serve(InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                     max_batch=2),
                     reqs, max_batched_tokens=16, chunked_prefill=True,
                     spec=SpecConfig(k=3, drafter="ngram"))
    assert m.scheduler == "unified" and m.spec_mode == "ngram"
    assert m.drafted_tokens > 0
    for uid, out in done.items():
        assert out == base[uid]


def test_chunked_kernel_interpret_matches_fallback(rng):
    """The mixed Pallas kernel (interpret mode) must not change greedy
    outputs vs the gather + jnp fallback on the chunked path."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, cfg, [(19, 4), (27, 4)])
    base, _ = _serve(InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                     max_batch=2),
                     reqs, max_batched_tokens=16, chunked_prefill=True)
    with KOPS.kernel_mode_ctx("interpret"):
        done, _ = _serve(InferenceEngine(cfg, params, policy=FP32,
                                         max_len=64, max_batch=2),
                         reqs, max_batched_tokens=16, chunked_prefill=True)
    for uid, out in done.items():
        assert out == base[uid]


def test_chunked_optout_family_falls_back(rng):
    """Forcing chunked prefill on a ring/recurrent-state family warns,
    serves via the bucketed path, and stays exact."""
    cfg = get_reduced("gemma2-2b")            # sliding-window ring
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(9, 4), (17, 4)])
    base, _ = _serve(InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                     max_batch=2),
                     reqs, chunked_prefill=False)
    with pytest.warns(UserWarning, match="chunked prefill requested"):
        done, m = _serve(eng, reqs, chunked_prefill=True)
    assert m.scheduler == "bucketed"
    for uid, out in done.items():
        assert out == base[uid]


def test_budget_floor_clamped_with_warning(rng):
    """A budget below one token per slot cannot make decode progress;
    the engine raises it to the floor, loudly, and still serves."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=3)
    reqs = _requests(rng, cfg, [(9, 4), (14, 4)])
    base, _ = _serve(InferenceEngine(cfg, params, policy=FP32, max_len=64,
                                     max_batch=3),
                     reqs, chunked_prefill=False)
    with pytest.warns(UserWarning, match="raising to"):
        done, m = _serve(eng, reqs, max_batched_tokens=1,
                         chunked_prefill=True)
    assert m.max_batched_tokens == 3          # slots * 1
    for uid, out in done.items():
        assert out == base[uid]


# ---------------------------------------------------------------------------
# Scheduler property tests: budget never exceeded, FCFS, no starvation
# ---------------------------------------------------------------------------


def _scheduler_invariant_trace(seed: int):
    rng = np.random.default_rng(seed)
    slots = int(rng.integers(2, 6))
    budget = int(rng.integers(slots, 40))
    sched = ContinuousScheduler(slots, PageAllocator(64), page_size=8,
                                max_pages_per_slot=16)
    n = int(rng.integers(3, 16))
    for uid in range(n):
        sched.submit(Request(uid=uid,
                             tokens=[1] * int(rng.integers(1, 50)),
                             max_new_tokens=int(rng.integers(1, 6))))
    iters = 0
    while sched.has_work():
        iters += 1
        assert iters < 5000, "scheduler failed to make progress"
        while sched.try_admit() is not None:
            pass
        plan = sched.next_batch(budget)
        # the budget is a hard per-iteration ceiling
        assert plan.total_tokens <= budget
        # decode first: every decoding slot is in the plan
        decoding = sorted(s for s, st in sched.slots.items()
                          if st.prefill_done)
        assert sorted(plan.decode_slots) == decoding
        admitting = [s for s, st in sched.slots.items()
                     if not st.prefill_done]
        if admitting:
            # FCFS, starvation-free: the oldest admitting slot always
            # receives the first (non-empty) chunk of the iteration
            oldest = min(admitting, key=lambda s: sched.slots[s].admit_seq)
            assert plan.chunks and plan.chunks[0].slot == oldest
            assert plan.chunks[0].length >= 1
        seqs = [sched.slots[c.slot].admit_seq for c in plan.chunks]
        assert seqs == sorted(seqs)           # chunks in admission order
        for c in plan.chunks:                 # contiguous, in-bounds
            st = sched.slots[c.slot]
            assert c.start == st.prefill_pos
            assert 1 <= c.length \
                <= st.request.prompt_len - st.prefill_pos
            st.prefill_pos += c.length        # apply the chunk
        for s in plan.decode_slots:           # emulate one decode token
            st = sched.slots[s]
            st.emitted.append(7)
            if len(st.emitted) >= st.request.max_new_tokens:
                sched.retire(s)
    sched.allocator.check()


def test_scheduler_budget_and_fcfs_seeded():
    for seed in range(50):
        _scheduler_invariant_trace(seed)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=50)
    @given(st.integers(0, 10_000))
    def test_scheduler_budget_and_fcfs_hypothesis(seed):
        _scheduler_invariant_trace(seed)


# ---------------------------------------------------------------------------
# Mixed paged-attention entry: ragged query counts vs oracle
# ---------------------------------------------------------------------------


def test_mixed_attention_ragged_vs_oracle(rng):
    B, P, page, npages, Hq, Hkv, D = 3, 7, 8, 3, 4, 2, 16
    W = 5
    kpool = jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32)
    vpool = jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32)
    ppos = np.full((P, page), -1, np.int32)
    bt = np.full((B, npages), -1, np.int32)
    ctx = [9, 14, 4]                           # stored context per slot
    perm = rng.permutation(P - 1)              # last page is the dump
    nxt_page = 0
    for b in range(B):
        used = -(-(ctx[b] + W) // page)
        bt[b, :used] = perm[nxt_page:nxt_page + used]
        nxt_page += used
        for t in range(ctx[b] + W):            # window K/V already written
            ppos[bt[b, t // page], t % page] = t
    # ragged per-slot query counts: decode row, chunk row, empty row
    n_q = np.asarray([1, W, 0], np.int32)
    q_pos = np.where(np.arange(W)[None, :] < n_q[:, None],
                     np.asarray(ctx)[:, None] + np.arange(W)[None, :],
                     -1).astype(np.int32)
    q = jnp.asarray(rng.normal(size=(B, W, Hq, D)), jnp.float32)
    assert DA.paged_mixed_shape_supported(q, kpool, jnp.asarray(bt))
    out = DA.paged_mixed_attention(
        q, kpool, vpool, jnp.asarray(ppos), jnp.asarray(bt),
        jnp.asarray(q_pos), window=None, scale=D ** -0.5, interpret=True)
    ref = R.paged_mixed_attention_ref(
        q, kpool, vpool, jnp.asarray(ppos), jnp.asarray(bt),
        jnp.asarray(q_pos), window=None, scale=D ** -0.5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # padding queries (q_pos == -1) are exactly zero
    assert not np.asarray(out[0, 1:]).any()
    assert not np.asarray(out[2]).any()


def test_forward_mixed_matches_decode_and_prefill(rng):
    """Model-level: one forward_mixed call carrying a decode row and a
    prefill-chunk row reproduces forward_decode / forward_prefill logits
    for the same tokens."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    page, npages, slots = 8, 8, 2
    toks = [list(map(int, rng.integers(4, 400, size=9))),
            list(map(int, rng.integers(4, 400, size=13)))]

    def fresh():
        return T.init_paged_cache(cfg, num_pages=npages, page_size=page,
                                  max_slots=slots, max_len=48,
                                  dtype=jnp.float32)

    bt = np.full((slots, 6), -1, np.int32)
    bt[0, :3] = [0, 1, 2]
    bt[1, :3] = [3, 4, 5]
    paged = {"block_tables": jnp.asarray(bt)}

    # reference: slot 0 prefilled whole, then one decode step; slot 1
    # prefilled whole (its last-token logits)
    cache = fresh()
    tok0 = jnp.asarray([toks[0] + [0] * 7, toks[1] + [0] * 3], jnp.int32)
    plens = jnp.asarray([9, 13], jnp.int32)
    lg_p, cache = T.forward_prefill(
        params, cfg, tok0, plens, cache, policy=FP32, max_len=48,
        last_only=True, paged={**paged, "active": jnp.ones((2,), bool)})
    nxt0 = int(jnp.argmax(lg_p[0, 0]))
    lg_d, cache = T.forward_decode(
        params, cfg, jnp.asarray([[nxt0], [0]], jnp.int32), cache,
        jnp.asarray([9, 13], jnp.int32), policy=FP32, max_len=48,
        paged={**paged, "active": jnp.asarray([True, False])})

    # mixed: slot 0 already prefilled -> decode row; slot 1 prefills its
    # last 5 tokens as a chunk (first 8 pre-written by a prefix call)
    cache2 = fresh()
    _, cache2 = T.forward_prefill(
        params, cfg, tok0, plens, cache2, policy=FP32, max_len=48,
        last_only=True, paged={**paged, "active": jnp.ones((2,), bool)})
    from repro.core import kv_cache as KV
    cache2 = KV.reset_pages_all(cache2, np.asarray(bt[1, :3]))
    _, cache2 = T.forward_prefill(
        params, cfg, jnp.asarray([toks[1][:8] + [0] * 5], jnp.int32),
        jnp.asarray([8], jnp.int32),
        KV.slot_view(cache2, 1), policy=FP32, max_len=48,
        last_only=True,
        paged={"block_tables": jnp.asarray(bt[1:2]),
               "active": jnp.ones((1,), bool)})
    W = 5
    mixed_toks = np.zeros((slots, W), np.int32)
    mixed_toks[0, 0] = nxt0
    mixed_toks[1, :5] = toks[1][8:]
    lg_m, _ = T.forward_mixed(
        params, cfg, jnp.asarray(mixed_toks), cache2,
        jnp.asarray([9, 8], jnp.int32), jnp.asarray([1, 5], jnp.int32),
        policy=FP32, max_len=48, paged=paged)
    np.testing.assert_allclose(np.asarray(lg_m[0, 0]),
                               np.asarray(lg_d[0, 0]), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(lg_m[1, 0]),
                               np.asarray(lg_p[1, 0]), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# TTFT / ITL metrics
# ---------------------------------------------------------------------------


def test_ttft_itl_recorded(rng):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    reqs = _requests(rng, cfg, [(9, 5), (21, 5), (5, 5)])
    _, m = _serve(eng, reqs, max_batched_tokens=16, chunked_prefill=True)
    # every request emitted a first token -> one TTFT sample each
    assert len(m.ttft_s) == len(reqs)
    assert all(t >= 0 for t in m.ttft_s)
    assert len(m.itl_s) > 0 and all(g >= 0 for g in m.itl_s)
    assert m.itl_p99 >= m.itl_p50 >= 0
    assert m.ttft_p99 >= m.ttft_p50 > 0


def test_ttft_itl_zero_guards():
    m = ServeMetrics()
    assert m.ttft_p50 == 0.0 and m.ttft_p99 == 0.0
    assert m.itl_p50 == 0.0 and m.itl_p99 == 0.0
