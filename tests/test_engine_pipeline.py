"""Serving engine + multi-stage pipeline (paper P1+P4 integration)."""
import jax
import numpy as np
import pytest

from repro.configs.registry import get_reduced
from repro.core import pipeline as PIPE
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.sampling import SamplingParams
from repro.core.scheduler import Request
from repro.core.tokenizer import FastTokenizer
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("unimo-text")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tok = FastTokenizer.train(
        ["the quick brown fox jumps over the lazy dog",
         "hello world of fast inference engines"], 256)
    return cfg, params, tok


def test_kv_equals_nocache_greedy(setup, rng):
    cfg, params, _ = setup
    e_kv = InferenceEngine(cfg, params, policy=FP32, max_len=64)
    e_nc = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                           use_kv_cache=False)
    toks = np.asarray(rng.integers(4, cfg.vocab_size, size=(3, 10)), np.int32)
    lens = np.array([10, 6, 3], np.int32)
    g1 = e_kv.generate_batch(toks.copy(), lens.copy(), 8)
    g2 = e_nc.generate_batch(toks.copy(), lens.copy(), 8)
    np.testing.assert_array_equal(g1, g2)
    assert e_kv.stats.decode_s > 0 and e_nc.stats.nocache_s > 0


def test_batched_equals_individual(setup, rng):
    """Dynamic batching must not change any request's greedy output."""
    cfg, params, _ = setup
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=4)
    toks = np.asarray(rng.integers(4, cfg.vocab_size, size=(4, 12)), np.int32)
    lens = np.array([12, 7, 12, 4], np.int32)
    gb = eng.generate_batch(toks.copy(), lens.copy(), 6)
    for b in range(4):
        g1 = eng.generate_batch(toks[b:b+1].copy(), lens[b:b+1].copy(), 6)
        np.testing.assert_array_equal(gb[b], g1[0], err_msg=f"row {b}")


def test_eos_stops_row(setup):
    cfg, params, _ = setup
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64)
    toks = np.full((1, 4), 5, np.int32)
    lens = np.array([4], np.int32)
    out = eng.generate_batch(toks, lens, 12)
    row = out[0]
    if (row == -1).any():
        first_pad = int(np.argmax(row == -1))
        assert (row[first_pad:] == -1).all()


def test_serve_requests_api(setup, rng):
    cfg, params, _ = setup
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=96, max_batch=3)
    reqs = [Request(uid=i,
                    tokens=[2] + list(rng.integers(4, 800, size=ln)),
                    max_new_tokens=5)
            for i, ln in enumerate([3, 9, 17, 4, 30])]
    done = eng.serve(reqs)
    assert all(r.result is not None and len(r.result) <= 5 for r in done)


def test_pipelined_equals_sequential(setup):
    cfg, params, tok = setup
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=96, max_batch=3)
    texts = ["the quick fox", "hello world", "lazy dog", "fast engines",
             "the the fox dog", "quick brown"]
    r_pipe = PIPE.run_pipelined(texts, tok, eng, max_new_tokens=5)
    r_seq = PIPE.run_sequential(texts, tok, eng, max_new_tokens=5)
    assert [r.uid for r in r_pipe] == list(range(len(texts)))
    for a, b in zip(r_pipe, r_seq):
        assert a.token_ids == b.token_ids
        assert a.text == b.text


def test_prefix_caching_equivalence(rng):
    """Beyond-paper prefix caching, now on the paged/radix path: a
    seeded shared prompt must not change greedy outputs, and the dense
    bucket path (``generate_batch``) is unaffected by seeding — its old
    per-bucket dense prefix rebuild is gone (requests carry the full
    prompt; sharing lives entirely in ``serve_continuous``).  Deep
    coverage (opt-out families, eviction, COW) lives in
    tests/test_prefix_cache.py."""
    import copy
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=96)
    prefix = [2] + list(map(int, rng.integers(4, 400, size=11)))
    suffixes = rng.integers(4, 400, size=(2, 5)).astype(np.int32)
    full = np.concatenate(
        [np.tile(prefix, (2, 1)).astype(np.int32), suffixes], axis=1)
    g_ref = eng.generate_batch(full.copy(),
                               np.full(2, full.shape[1], np.int32), 5)
    reqs = [Request(uid=i, tokens=[int(t) for t in full[i]],
                    max_new_tokens=5) for i in range(2)]
    eng.set_prefix(prefix, page_size=8)   # geometry matches the serve below
    # dense path ignores the seeded prefix (full prompts, same output)
    g_again = eng.generate_batch(full.copy(),
                                 np.full(2, full.shape[1], np.int32), 5)
    np.testing.assert_array_equal(g_ref, g_again)
    # paged path hits it at admission and stays exact
    done, metrics = eng.serve_continuous(copy.deepcopy(reqs), page_size=8)
    for i, r in enumerate(done):
        ref_row = g_ref[i]
        assert r.result == [int(t) for t in ref_row[ref_row >= 0]][:5]
    assert metrics.prefix_hits == len(reqs)
    eng.clear_prefix()


def test_sampling_params_temperature(setup, rng):
    cfg, params, _ = setup
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=48, seed=7)
    toks = np.asarray(rng.integers(4, 800, size=(1, 6)), np.int32)
    lens = np.array([6], np.int32)
    g1 = eng.generate_batch(toks.copy(), lens.copy(), 8,
                            SamplingParams(temperature=1.0, top_k=20))
    assert g1.shape == (1, 8)
    assert ((g1 >= -1) & (g1 < cfg.vocab_size)).all()
