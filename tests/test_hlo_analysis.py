"""The trip-count-aware HLO analyzer that feeds the roofline tables."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as HA


def _flops(fn, *args):
    hlo = jax.jit(fn).lower(*args).compile().as_text()
    return HA.analyze(hlo)


def test_single_matmul_flops():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    res = _flops(lambda a, b: a @ b, x, w)
    assert res["flops"] == 2 * 256 * 512 * 128
    assert res["n_dots"] == 1


def test_scan_multiplies_by_trip_count():
    """The exact failure mode of raw cost_analysis: scan bodies count once.
    Our analyzer must multiply by the trip count."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)

    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    res = _flops(f, x, ws)
    one = 2 * 128 * 128 * 128
    assert abs(res["flops"] - 12 * one) / (12 * one) < 0.05, res["flops"]


def test_nested_scan():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 64, 64), jnp.float32)

    def inner(c, w):
        def body(c2, _):
            return c2 @ w, None
        y, _ = jax.lax.scan(body, c, None, length=5)
        return y, None

    def f(x, ws):
        y, _ = jax.lax.scan(inner, x, ws)
        return y

    res = _flops(f, x, ws)
    one = 2 * 64 * 64 * 64
    assert abs(res["flops"] - 15 * one) / (15 * one) < 0.05, res["flops"]


def test_bytes_nonzero_and_scaled():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

    def f(a):
        return (a * 2.0 + 1.0).sum()

    res = _flops(f, x)
    # at least one read of the 4MB input
    assert res["bytes"] >= 4 * 1024 * 1024


def test_collective_parse_synthetic():
    hlo = """
HloModule test

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %x = f32[4] get-tuple-element(%p), index=1
  %ar = f32[4]{0} all-reduce(%x), replica_groups={}, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4]) tuple(%i, %ar)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4] parameter(0)
  %ag = f32[8]{0} all-gather(%a), dimensions={0}
  %w = (s32[], f32[4]) while(%init), condition=%cond, body=%body
  ROOT %r = f32[4] get-tuple-element(%w), index=1
}
"""
    res = HA.analyze(hlo)
    per = res["collectives"]["per_op_bytes"]
    assert per["all-gather"] == 32            # 8 * 4B, once
    assert per["all-reduce"] == 7 * 16        # 4 * 4B, 7 trips
