"""Overload-survivable serving (PR-6 tentpole).

Contracts under test:
  * offload -> restore is bit-identical for bf16/fp32 AND int8 pools
    (codes + scale pools + positions round-trip byte-exact);
  * preemption + host KV offload: under pool pressure (demand ~3x the
    pool) every request still completes, greedy outputs bit-identical
    to an uncontended run across {plain, prefix-shared, int8,
    speculative}, allocator audit clean;
  * recompute-resume (host tier absent or full) stays bit-identical —
    degraded in compute, never in results;
  * refcounts never go negative across preempt/restore, and preempting
    one sharer never disturbs pages another reader maps (COW/sharing
    safety);
  * deadlines / max_queue_wait cancel queued work with structured
    timed_out outcomes; completions past deadline count misses;
  * every injected fault (pool exhaustion, host-tier-full, oversized
    prompts, arrival bursts) ends every request in a terminal
    RequestOutcome with no deadlock and a clean per-iteration audit;
  * the trie spills evicted leaves to host and re-promotes them on a
    later match;
  * new ServeMetrics fields are zero-guarded like the existing ones.
"""
import copy
import dataclasses

import jax
import numpy as np
import pytest

try:
    # when hypothesis is installed (CI installs it), the invariant
    # harness below also runs as a generative property test
    from hypothesis import given, settings, strategies as st
    settings.register_profile("overload", deadline=None, max_examples=20)
    settings.load_profile("overload")
    HAVE_HYPOTHESIS = True
except ImportError:                    # seeded fallback still runs
    HAVE_HYPOTHESIS = False

from repro.configs.registry import get_reduced
from repro.core import kv_cache as KV
from repro.core.continuous import (ContinuousScheduler, FaultConfig,
                                   HostKVStore, PageAllocator, ServeMetrics)
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.prefix_cache import RadixPrefixCache
from repro.core.scheduler import TERMINAL_STATUSES, Request
from repro.models import transformer as T

INT8 = dataclasses.replace(FP32, kv_dtype="int8")


# ---------------------------------------------------------------------------
# HostKVStore accounting
# ---------------------------------------------------------------------------


def _blob(nbytes):
    """A fake offload blob of ``nbytes`` host bytes (one stack, one
    paged layer)."""
    return [[{"pk": np.zeros(nbytes, np.int8)}]]


def test_host_store_budget_and_lru():
    hs = HostKVStore(max_bytes=100)
    assert hs.put("a", _blob(40)) and hs.put("b", _blob(40))
    assert hs.used_bytes == 80
    hs.peek("a")                           # refresh a: b becomes LRU
    assert hs.put("c", _blob(40))          # evicts b
    assert "b" not in hs and "a" in hs and "c" in hs
    assert hs.spill_evictions == 1 and hs.used_bytes == 80
    hs.check()


def test_host_store_nonevictable_protected():
    hs = HostKVStore(max_bytes=100)
    assert hs.put("pinned", _blob(80), evictable=False)
    assert not hs.put("big", _blob(50))    # cannot evict the pinned entry
    assert hs.refused_puts == 1 and "pinned" in hs
    assert hs.pop("pinned") is not None
    assert hs.used_bytes == 0
    hs.check()


def test_host_store_overwrite_and_zero_budget():
    hs = HostKVStore(max_bytes=100)
    hs.put("k", _blob(60))
    assert hs.put("k", _blob(30))          # replace: bytes re-accounted
    assert hs.used_bytes == 30 and len(hs) == 1
    full = HostKVStore(max_bytes=0)        # the host-tier-full fault mode
    assert not full.put("x", _blob(1))
    assert full.used_bytes == 0 and full.refused_puts == 1
    hs.check(), full.check()


def test_host_store_unbounded():
    hs = HostKVStore(max_bytes=None)
    for i in range(5):
        assert hs.put(i, _blob(1000))
    assert hs.used_bytes == 5000 and hs.peak_bytes == 5000
    hs.check()


# ---------------------------------------------------------------------------
# offload_pages / restore_pages round-trip
# ---------------------------------------------------------------------------


def _fill_pool(cache, rng):
    """Write random bytes into every paged leaf so the round-trip has
    real content to preserve."""
    layers = []
    for stack_c in cache["layers"]:
        row = []
        for c in stack_c:
            if isinstance(c, dict) and "ppos" in c:
                c = dict(c)
                for k in KV.PAGED_KEYS:
                    if k in c:
                        a = c[k]
                        if a.dtype == np.int32:
                            val = rng.integers(-1, 50, size=a.shape)
                        else:
                            val = rng.normal(size=a.shape) * 3
                        c[k] = a.at[...].set(
                            np.asarray(val).astype(a.dtype))
            row.append(c)
        layers.append(tuple(row))
    return {"layers": tuple(layers)}


@pytest.mark.parametrize("kv_dtype", ["auto", "int8"])
def test_offload_restore_bit_identical(rng, kv_dtype):
    cfg = get_reduced("qwen3-4b")
    cache = T.init_paged_cache(cfg, num_pages=8, page_size=8, max_slots=2,
                               max_len=64, dtype=np.float32,
                               kv_dtype=kv_dtype)
    cache = _fill_pool(cache, rng)
    pages = [1, 3, 6]
    blob = KV.offload_pages(cache, pages)
    assert KV.blob_bytes(blob) > 0
    if kv_dtype == "int8":
        # the blob must carry the quantized codes AND the scale pools
        leaf = next(d for row in blob for d in row if d)
        assert {"pk", "pv", "ppos", "pk_scale", "pv_scale"} <= set(leaf)
    # clobber the offloaded pages, restore into different ones, compare
    clobbered = KV.reset_pages_all(cache, np.asarray(pages))
    dst = [0, 2, 5]
    restored = KV.restore_pages(clobbered, blob, dst)
    for stack_i, stack_c in enumerate(cache["layers"]):
        for li, c in enumerate(stack_c):
            if not (isinstance(c, dict) and "ppos" in c):
                continue
            rc = restored["layers"][stack_i][li]
            rep = c["ppos"].ndim == 3      # leading scan-repeats dim
            for k in KV.PAGED_KEYS:
                if k not in c:
                    continue
                src_v = np.asarray(c[k][:, pages] if rep else c[k][pages])
                dst_v = np.asarray(rc[k][:, dst] if rep else rc[k][dst])
                np.testing.assert_array_equal(src_v, dst_v)


# ---------------------------------------------------------------------------
# Preemption end-to-end: bit-identical under ~3x pool pressure
# ---------------------------------------------------------------------------


def _reqs(rng, cfg, shapes, prefix=None, **kw):
    prefix = prefix or []
    return [Request(uid=i,
                    tokens=[2] + prefix + list(map(int, rng.integers(
                        4, min(cfg.vocab_size, 400), size=ln))),
                    max_new_tokens=mn, **kw)
            for i, (ln, mn) in enumerate(shapes)]


def _serve(eng, reqs, **kw):
    done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   chunked_prefill=True,
                                   max_batched_tokens=16, **kw)
    return {r.uid: r.result for r in done}, m, done


@pytest.fixture(scope="module")
def qwen():
    cfg = get_reduced("qwen3-4b")
    return cfg, T.init_params(jax.random.PRNGKey(0), cfg)


# demand: 6 requests x 5 pages = 30 pages; pool: 11 pages (~1/3)
SHAPES = [(30, 8), (28, 8), (26, 8), (24, 8), (22, 8), (20, 8)]
POOL = 11


@pytest.mark.parametrize("mode", ["plain", "prefix", "int8", "spec"])
def test_preempt_offload_bit_identical(rng, qwen, mode):
    cfg, params = qwen
    policy = INT8 if mode == "int8" else FP32
    prefix = list(map(int, rng.integers(4, 400, size=16))) \
        if mode == "prefix" else None
    shapes = [(ln - 16, mn) for ln, mn in SHAPES] if prefix else SHAPES
    reqs = _reqs(rng, cfg, shapes, prefix=prefix)
    spec = None
    if mode == "spec":
        from repro.core.speculative import SpecConfig
        spec = SpecConfig(k=3, drafter="ngram")

    def eng():
        return InferenceEngine(cfg, params, policy=policy, max_len=64,
                               max_batch=3)

    base, _, _ = _serve(eng(), reqs, spec=spec)
    out, m, done = _serve(eng(), reqs, spec=spec, num_pages=POOL,
                          preemption="lru", host_kv_bytes=1 << 30,
                          debug_audit=True)
    assert m.preemptions >= 1 and m.resumed == m.preemptions
    assert m.offloaded_pages > 0 and m.restored_pages > 0
    assert m.host_bytes_peak > 0
    for r in done:
        assert r.outcome is not None \
            and r.outcome.status in TERMINAL_STATUSES
        assert r.outcome.status == "completed"
    assert out == base, f"preempted outputs diverged ({mode})"


def test_recompute_resume_bit_identical(rng, qwen):
    """No host tier at all: preemption falls back to re-prefilling the
    context — slower, still bit-identical."""
    cfg, params = qwen
    reqs = _reqs(rng, cfg, SHAPES)

    def eng():
        return InferenceEngine(cfg, params, policy=FP32, max_len=64,
                               max_batch=3)

    base, _, _ = _serve(eng(), reqs)
    out, m, _ = _serve(eng(), reqs, num_pages=POOL, preemption="lru",
                       debug_audit=True)
    assert m.preemptions >= 1 and m.offloaded_pages == 0
    assert out == base


def test_host_full_fault_degrades_to_recompute(rng, qwen):
    cfg, params = qwen
    reqs = _reqs(rng, cfg, SHAPES)

    def eng():
        return InferenceEngine(cfg, params, policy=FP32, max_len=64,
                               max_batch=3)

    base, _, _ = _serve(eng(), reqs)
    out, m, done = _serve(eng(), reqs, num_pages=POOL, preemption="lru",
                          host_kv_bytes=1 << 30,
                          faults=FaultConfig(host_full=True),
                          debug_audit=True)
    assert m.preemptions >= 1 and m.offloaded_pages == 0
    assert all(r.outcome.status in TERMINAL_STATUSES for r in done)
    assert out == base


def test_priority_policy_prefers_low_priority_victims(rng, qwen):
    """A high-priority blocked head evicts low-priority work; an
    equal-priority head never preempts (strict inequality)."""
    cfg, params = qwen
    reqs = _reqs(rng, cfg, SHAPES[:4])
    reqs[2].priority = 5                   # becomes the blocked head

    def eng():
        return InferenceEngine(cfg, params, policy=FP32, max_len=64,
                               max_batch=3)

    base, _, _ = _serve(eng(), reqs)
    out, m, done = _serve(eng(), reqs, num_pages=POOL,
                          preemption="priority", host_kv_bytes=1 << 30,
                          debug_audit=True)
    assert m.preemptions >= 1
    by_uid = {r.uid: r for r in done}
    assert by_uid[2].preemptions == 0      # priority 5 never evicted
    assert out == base

    # all equal priority -> strictly-greater rule disables preemption
    _, m2, _ = _serve(eng(), _reqs(rng, cfg, SHAPES[:4]), num_pages=POOL,
                      preemption="priority", host_kv_bytes=1 << 30)
    assert m2.preemptions == 0


def test_max_preemptions_caps_churn(rng, qwen):
    cfg, params = qwen
    reqs = _reqs(rng, cfg, SHAPES)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                          max_batch=3)
    _, m, done = _serve(eng, reqs, num_pages=POOL, preemption="lru",
                        host_kv_bytes=1 << 30, max_preemptions=1)
    assert all(r.preemptions <= 1 for r in done)
    assert all(r.outcome.status == "completed" for r in done)


def test_preemption_requires_chunked_scheduler(rng, qwen):
    cfg, params = qwen
    reqs = _reqs(rng, cfg, SHAPES[:2])
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                          max_batch=3)
    with pytest.warns(UserWarning, match="preemption requested"):
        done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                       chunked_prefill=False,
                                       preemption="lru")
    assert m.preemptions == 0
    assert all(r.outcome.status == "completed" for r in done)


# ---------------------------------------------------------------------------
# Deadlines / backpressure
# ---------------------------------------------------------------------------


def test_deadline_cancels_queued_work(rng, qwen):
    cfg, params = qwen
    reqs = _reqs(rng, cfg, [(20, 6), (18, 6), (16, 6)])
    reqs[2].deadline = -1.0                # expired before it can start
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                          max_batch=2)
    done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   chunked_prefill=True,
                                   max_batched_tokens=16)
    by_uid = {r.uid: r for r in done}
    assert by_uid[2].outcome.status == "timed_out"
    assert by_uid[2].outcome.deadline_missed
    assert by_uid[2].result == []
    assert m.timed_out == 1 and m.deadline_misses >= 1
    assert m.outcome_counts["timed_out"] == 1
    assert by_uid[0].outcome.status == "completed"


def test_max_queue_wait_cancels_stuck_head(rng, qwen):
    cfg, params = qwen
    reqs = _reqs(rng, cfg, [(30, 8), (28, 8), (26, 8), (24, 8)])
    for r in reqs[2:]:
        r.max_queue_wait = 0.0             # cancel the moment they queue
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                          max_batch=2)
    done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   num_pages=POOL, chunked_prefill=True,
                                   max_batched_tokens=16)
    by_uid = {r.uid: r for r in done}
    assert by_uid[2].outcome.status == "timed_out"
    assert by_uid[3].outcome.status == "timed_out"
    assert by_uid[0].outcome.status == "completed"
    assert m.timed_out == 2


def test_completed_past_deadline_counts_miss():
    """A request that is already running at its deadline completes (we
    never cancel in-flight work) but books a deadline miss.  Scheduler
    level: the serve clock is wall time, so this is the deterministic
    way to pin the retire-past-deadline path."""
    alloc = PageAllocator(8)
    sched = ContinuousScheduler(1, alloc, page_size=4)
    req = Request(uid=0, tokens=[1, 2, 3], max_new_tokens=2, deadline=0.5)
    sched.submit(req, 0.0)
    slot, st = sched.try_admit(0.1)
    st.prefill_pos = st.ctx_len
    st.emitted.extend([5, 6])
    sched.retire(slot, now=1.0)            # finishes past the deadline
    assert req.outcome.status == "completed"
    assert req.outcome.deadline_missed
    assert req.result == [5, 6]
    alloc.check()


# ---------------------------------------------------------------------------
# Fault-injection suite: graceful degradation, never deadlock
# ---------------------------------------------------------------------------


FAULTS = [
    FaultConfig(hold_pages=6, hold_after_admits=2),
    FaultConfig(host_full=True),
    FaultConfig(oversize_uids=(1, 3)),
    FaultConfig(collapse_arrivals=True),
    FaultConfig(hold_pages=8, host_full=True, oversize_uids=(0,),
                collapse_arrivals=True),
]


@pytest.mark.parametrize("fault", FAULTS)
def test_fault_injection_terminal_outcomes(rng, qwen, fault):
    cfg, params = qwen
    reqs = _reqs(rng, cfg, SHAPES)
    for r in reqs[3:]:
        r.max_queue_wait = 20.0            # bounded even under pool theft
    arrivals = [0.0, 0.0, 0.05, 0.05, 0.1, 0.1]
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                          max_batch=3)
    done, m = eng.serve_continuous(
        copy.deepcopy(reqs), page_size=8, num_pages=POOL,
        chunked_prefill=True, max_batched_tokens=16, arrivals=arrivals,
        preemption="lru", host_kv_bytes=1 << 30, faults=fault,
        debug_audit=True)
    assert len(done) == len(reqs)
    for r in done:
        assert r.outcome is not None, f"request {r.uid} has no outcome"
        assert r.outcome.status in TERMINAL_STATUSES
        assert r.result is not None
    # the audit ran every iteration and the end-of-run leak check passed
    # inside serve_continuous; outcome counts cover every request
    assert sum(m.outcome_counts.values()) == len(reqs)


def test_oversize_fault_truncates_or_rejects(rng, qwen):
    cfg, params = qwen
    reqs = _reqs(rng, cfg, [(10, 4), (10, 4)])
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                          max_batch=2)
    with pytest.warns(UserWarning, match="exceeds the maximum"):
        done, m = eng.serve_continuous(
            copy.deepcopy(reqs), page_size=8, chunked_prefill=True,
            max_batched_tokens=16,
            faults=FaultConfig(oversize_uids=(1,)), debug_audit=True)
    by_uid = {r.uid: r for r in done}
    assert by_uid[0].outcome.status == "completed"
    # the inflated prompt was truncated to fit and served to completion
    assert by_uid[1].outcome.status == "truncated"
    assert by_uid[1].result


# ---------------------------------------------------------------------------
# Trie spill -> host -> promote
# ---------------------------------------------------------------------------


def test_trie_spill_and_promote(rng, qwen):
    """Evicted prefix pages demote to host; a later admission matching
    the spilled span restores it into a fresh device page instead of
    re-prefilling."""
    cfg, params = qwen
    prefix = list(map(int, rng.integers(4, 400, size=23)))
    reqs = _reqs(rng, cfg, [(8, 6)], prefix=prefix)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                          max_batch=2)
    base, m0, _ = _serve(eng, reqs, host_kv_bytes=1 << 30)
    trie = eng._paged_ctx["trie"]
    host = eng._paged_ctx["host"]
    # force-evict everything the trie holds (as pool pressure would)
    spilled_before = trie.spilled_pages
    trie.host_store = host
    trie.offload_fn = lambda pages: KV.offload_pages(
        eng._paged_ctx["cache"], pages)
    trie.evict(64)
    assert trie.spilled_pages > spilled_before
    assert len(host) > 0
    trie.offload_fn = None
    # same prefix again: the spilled spans promote back device-side
    reqs2 = [Request(uid=9, tokens=reqs[0].tokens[:24] + [7, 8, 9],
                     max_new_tokens=6)]
    out2, m2, _ = _serve(eng, reqs2, host_kv_bytes=1 << 30)
    assert m2.restored_pages > 0
    assert m2.prefix_matched_tokens > 0


# ---------------------------------------------------------------------------
# Scheduler-level invariants (hypothesis + seeded fallback)
# ---------------------------------------------------------------------------


def _stub_sched(num_pages=24, slots=3, preemption="lru", host=None):
    alloc = PageAllocator(num_pages)
    trie = RadixPrefixCache(alloc, page_size=8)
    sched = ContinuousScheduler(slots, alloc, page_size=8,
                                max_pages_per_slot=8, prefix_cache=trie,
                                match_prefix=True, preemption=preemption)
    sched.host_store = host
    # device-free stubs: a blob is just the page list it snapshotted
    sched.offload_fn = lambda pages: [[{"pk": np.zeros(
        (len(pages), 8), np.int8)}]]
    sched.restore_fn = lambda blob, pages: None
    return sched, alloc, trie


def _preempt_resume_trace(seed: int):
    """Randomized admit/decode/preempt/cancel/retire sequences: the
    allocator must audit clean after EVERY operation, refcounts can
    never go negative, and every request ends terminal."""
    rng = np.random.default_rng(seed)
    host = HostKVStore(max_bytes=int(rng.integers(0, 4000))) \
        if rng.random() < 0.7 else None
    sched, alloc, trie = _stub_sched(
        num_pages=int(rng.integers(12, 40)),
        slots=int(rng.integers(2, 5)),
        preemption=["lru", "priority"][int(rng.integers(0, 2))],
        host=host)
    n = int(rng.integers(4, 12))
    reqs = [Request(uid=u,
                    tokens=[1] + list(map(int, rng.integers(2, 9, size=int(
                        rng.integers(4, 40))))),
                    max_new_tokens=int(rng.integers(2, 8)),
                    priority=int(rng.integers(0, 3)))
            for u in range(n)]
    for r in reqs:
        sched.submit(r, 0.0)
    terminal = set()
    steps = 0
    while sched.has_work():
        steps += 1
        assert steps < 4000, "scheduler live/deadlocked"
        op = rng.random()
        for req in sched.cancel_expired(float(steps)):
            terminal.add(req.uid)
        alloc.check()
        if op < 0.5:
            adm = sched.try_admit(float(steps))
            if adm is None and sched.waiting and sched.free_slots():
                head = sched.waiting[0]
                if sched.queued_pages_needed(head) \
                        <= sched.preemptible_headroom(head):
                    v = sched.pick_victim(head)
                    if v is not None:
                        st = sched.slots[v]
                        sched.preempt(
                            v, pending=st.emitted[-1],
                            ctx_len=(len(st.request.tokens)
                                     + len(st.emitted) - 1),
                            rem_tokens=2)
            elif adm is not None:
                _, st = adm
                st.prefill_pos = st.ctx_len          # instant prefill
                if not st.emitted:
                    st.emitted.append(7)
        elif op < 0.8 and sched.slots:
            s = int(rng.choice(list(sched.slots)))
            st = sched.slots[s]
            if st.prefill_done:
                st.emitted.append(7)
                if len(st.emitted) >= st.request.max_new_tokens:
                    sched.retire(s, float(steps))
                    terminal.add(st.request.uid)
        elif sched.waiting and rng.random() < 0.2:
            sched.waiting[0].max_queue_wait = -1.0   # doom the head
        alloc.check()
        if host is not None:
            host.check()
    for r in reqs:
        assert r.uid in terminal or r.outcome is not None \
            or r.result is not None
        if r.outcome is not None:
            assert r.outcome.status in TERMINAL_STATUSES
    alloc.check()


def test_preempt_resume_invariants_seeded():
    for seed in range(40):
        _preempt_resume_trace(seed)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=40)
    @given(st.integers(0, 10_000))
    def test_preempt_resume_invariants_hypothesis(seed):
        _preempt_resume_trace(seed)


def test_preempt_never_disturbs_other_readers():
    """COW/sharing safety: preempting one sharer must not change the
    refcounts or mappings of pages another slot still reads."""
    alloc = PageAllocator(16)
    trie = RadixPrefixCache(alloc, page_size=4)
    sched = ContinuousScheduler(2, alloc, page_size=4,
                                max_pages_per_slot=8, prefix_cache=trie,
                                preemption="lru")
    sched.host_store = HostKVStore()
    offloaded = []
    sched.offload_fn = lambda pages: (offloaded.append(list(pages)),
                                      [[{"pk": np.zeros(2, np.int8)}]])[1]
    sched.restore_fn = lambda blob, pages: None
    shared_toks = [1, 2, 3, 4, 5, 6, 7, 8]
    ra = Request(uid=0, tokens=shared_toks + [9], max_new_tokens=4)
    rb = Request(uid=1, tokens=shared_toks + [11], max_new_tokens=4)
    sched.submit(ra), sched.submit(rb)
    sa, sta = sched.try_admit()
    sta.prefill_pos = sta.ctx_len
    sta.emitted.append(7)
    sched.insert_prefix(sta, 8)            # both full pages join the trie
    sb, stb = sched.try_admit()
    stb.prefill_pos = stb.ctx_len
    stb.emitted.append(7)
    assert stb.shared_count == 2           # B maps A's two prefix pages
    shared_pages = stb.pages[:2]
    before = [alloc.refcount(p) for p in shared_pages]
    assert all(c >= 3 for c in before)     # trie + A + B
    sched.preempt(sa, pending=7, ctx_len=9, rem_tokens=3)
    # A's snapshot covered its pages (shared prefix included, read-only),
    # but the shared pages only lost A's reference — B still reads them
    assert offloaded and set(shared_pages) <= set(offloaded[0])
    after = [alloc.refcount(p) for p in shared_pages]
    assert after == [c - 1 for c in before]
    assert all(alloc.refcount(p) >= 2 for p in shared_pages)
    alloc.check()
    sched.retire(sb)
    alloc.check()


# ---------------------------------------------------------------------------
# Metrics zero-guards
# ---------------------------------------------------------------------------


def test_overload_metrics_zero_guards():
    m = ServeMetrics()
    assert m.preemptions == 0 and m.resumed == 0
    assert m.offloaded_pages == 0 and m.restored_pages == 0
    assert m.host_bytes_used == 0 and m.host_bytes_peak == 0
    assert m.timed_out == 0 and m.deadline_misses == 0
    assert m.outcome_counts == {}
    # existing derived guards still hold on an empty run
    assert m.decode_idle_frac == 0.0 and m.acceptance_rate == 0.0
    assert m.prefix_hit_rate == 0.0 and m.itl_p99 == 0.0
