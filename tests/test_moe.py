"""MoE dispatch: sort-based capacity routing vs a dense per-token oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.configs.registry import get_reduced
from repro.models import moe as MOE


def _oracle(cfg, p, x, kind):
    """Dense per-token expert mixture (no capacity, no dispatch)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    m = cfg.moe
    scores = xf @ p["router"]
    if kind == "sigmoid":
        probs = jax.nn.sigmoid(scores)
    else:
        probs = jax.nn.softmax(scores, -1)
    topw, tope = jax.lax.top_k(probs, m.top_k)
    topw = topw / (topw.sum(-1, keepdims=True) + 1e-9)
    outs = []
    for e in range(m.num_experts):
        h = xf @ p["wi"][e]
        g = jax.nn.silu(xf @ p["wg"][e])
        outs.append((g * h) @ p["wo"][e])
    outs = jnp.stack(outs, 1)                       # (T, E, d)
    w_full = jnp.zeros((xf.shape[0], m.num_experts)).at[
        jnp.arange(xf.shape[0])[:, None], tope].set(topw)
    out = jnp.einsum("te,ted->td", w_full, outs)
    if "shared" in p:
        from repro.models import layers as L
        out = out + L.ffn_apply(cfg, p["shared"], xf)
    return out.reshape(B, S, d)


@pytest.mark.parametrize("arch,kind", [("qwen3-moe-235b-a22b", "softmax"),
                                       ("deepseek-v3-671b", "sigmoid")])
def test_moe_matches_dense_oracle(arch, kind, key, rng):
    cfg = get_reduced(arch)
    # generous capacity -> no token drops -> exact match expected
    cfg = cfg.replace(moe=MoEConfig(
        num_experts=cfg.moe.num_experts, top_k=cfg.moe.top_k,
        num_shared_experts=cfg.moe.num_shared_experts,
        d_ff_expert=cfg.moe.d_ff_expert, capacity_factor=8.0))
    p = MOE.moe_init(key, cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32) * 0.3
    out, aux = MOE.moe_apply(cfg, p, x, kind)
    ref = _oracle(cfg, p, x, kind)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_are_bounded(key, rng):
    """With tight capacity some tokens drop, output stays finite and the
    kept fraction is >= capacity/perfect-balance bound."""
    cfg = get_reduced("qwen3-moe-235b-a22b")
    cfg = cfg.replace(moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                    capacity_factor=0.5))
    p = MOE.moe_init(key, cfg)
    x = jnp.asarray(rng.normal(size=(4, 32, cfg.d_model)), jnp.float32)
    out, aux = MOE.moe_apply(cfg, p, x, "softmax")
    assert bool(jnp.isfinite(out).all())


def test_moe_aux_balanced_router_lower_than_collapsed(key, rng):
    cfg = get_reduced("qwen3-moe-235b-a22b")
    p = MOE.moe_init(key, cfg)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)), jnp.float32)
    _, aux_rand = MOE.moe_apply(cfg, p, x, "softmax")
    # collapse router to a single expert
    p2 = dict(p)
    bias = jnp.zeros_like(p["router"]).at[:, 0].set(50.0)
    p2["router"] = p["router"] * 0.0 + bias
    _, aux_coll = MOE.moe_apply(cfg, p2, x, "softmax")
    assert float(aux_coll) > float(aux_rand)


@pytest.mark.parametrize("kind", ["softmax", "sigmoid"])
def test_moe_ragged_matches_capacity(kind, key, rng):
    """Beyond-paper ragged_dot dispatch == capacity dispatch when capacity
    is generous (no drops)."""
    cfg = get_reduced("qwen3-moe-235b-a22b")
    cfg = cfg.replace(moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                    capacity_factor=8.0))
    p = MOE.moe_init(key, cfg)
    x = jnp.asarray(rng.normal(size=(2, 24, cfg.d_model)),
                    jnp.float32) * 0.3
    out_r, aux_r = MOE.moe_apply_ragged(cfg, p, x, kind)
    out_c, aux_c = MOE.moe_apply_capacity(cfg, p, x, kind)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(out_c),
                               rtol=2e-5, atol=2e-5)
    assert abs(float(aux_r) - float(aux_c)) < 1e-6
    g = jax.grad(lambda q: MOE.moe_apply_ragged(cfg, q, x, kind)[0].sum())(p)
    assert float(jnp.abs(g["wi"]).sum()) > 0


def test_moe_grad_flows(key, rng):
    cfg = get_reduced("qwen3-moe-235b-a22b")
    p = MOE.moe_init(key, cfg)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)

    def loss(p):
        out, aux = MOE.moe_apply(cfg, p, x, "softmax")
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    gnorm = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0
    assert float(jnp.abs(g["router"]).sum()) > 0   # router receives gradient
