"""Speculative decoding subsystem (PR-4 tentpole).

Contracts under test:
  * top_p (nucleus) sampling filters exactly like a sorted-cumsum
    numpy reference, and the drawn distribution matches the renormalized
    nucleus;
  * the rejection sampler is distribution preserving (empirically: the
    combined accept-or-resample output of a drafted position is the
    target distribution), and is exact-match greedy at temperature 0;
  * drafters: n-gram prompt lookup proposes the continuation of the most
    recent match; the draft-model drafter proposes its own greedy
    continuation;
  * multi-token paged write + truncate: rollback across a page boundary,
    on int8 pools (stale codes/scales unreachable), never wraps a ring,
    and PageAllocator invariants hold after randomized accept/reject
    serving (hypothesis + seeded fallback);
  * the multi-query paged verify Pallas kernel matches the ref.py oracle
    (fp and int8, GQA, window, softcap), and forward_verify is
    bit-identical to sequential forward_decode;
  * serve_continuous with speculation (both drafters) emits bit-identical
    greedy streams vs non-speculative serving — with prefix sharing on
    and off, with kv_dtype=int8, across EOS/budget edges — and the
    ServeMetrics speculative counters behave (zero guards included).
"""
import copy
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    settings.register_profile("spec", deadline=None, max_examples=15)
    settings.load_profile("spec")
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.registry import get_reduced
from repro.core import kv_cache as KV
from repro.core import sampling as SMP
from repro.core import speculative as SPEC
from repro.core.continuous import ServeMetrics
from repro.core.engine import InferenceEngine
from repro.core.precision import FP32
from repro.core.sampling import SamplingParams
from repro.core.scheduler import Request
from repro.core.tokenizer import EOS
from repro.kernels import decode_attention as DA
from repro.kernels import ops as KOPS
from repro.kernels import ref as R
from repro.models import transformer as T

INT8 = dataclasses.replace(FP32, kv_dtype="int8")


# ---------------------------------------------------------------------------
# top_p (nucleus) sampling
# ---------------------------------------------------------------------------


def _nucleus_reference(logits, top_p):
    """Independent numpy nucleus filter: smallest top set reaching
    top_p (the crossing token included)."""
    order = np.argsort(-logits)
    probs = np.exp(logits - logits.max())
    probs /= probs.sum()
    cum = np.cumsum(probs[order])
    cut = int(np.searchsorted(cum, top_p) + 1)       # include the crosser
    return set(order[:cut].tolist())


@pytest.mark.parametrize("top_p", [0.1, 0.5, 0.9])
def test_top_p_filters_to_nucleus(rng, top_p):
    logits = rng.normal(size=(16,)).astype(np.float32) * 3.0
    keep = _nucleus_reference(logits, top_p)
    sp = SamplingParams(temperature=1.0, top_p=top_p)
    keys = jax.random.split(jax.random.PRNGKey(0), 64)
    draws = {int(SMP.sample(jnp.asarray(logits)[None], k_, sp)[0])
             for k_ in keys}
    assert draws <= keep
    # filtered probs match the renormalized nucleus exactly
    p = np.asarray(SMP.target_probs(jnp.asarray(logits), sp))
    probs = np.exp(logits - logits.max())
    probs /= probs.sum()
    want = np.where([i in keep for i in range(16)], probs, 0.0)
    want /= want.sum()
    np.testing.assert_allclose(p, want, rtol=1e-5, atol=1e-6)


def test_top_p_one_is_identity_and_combines_with_top_k(rng):
    logits = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
    sp_full = SamplingParams(temperature=0.7)
    p = np.asarray(SMP.target_probs(logits, sp_full))
    np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
    sp_both = SamplingParams(temperature=0.7, top_k=8, top_p=0.6)
    pb = np.asarray(SMP.target_probs(logits, sp_both))
    assert ((pb > 0).sum(-1) <= 8).all()
    np.testing.assert_allclose(pb.sum(-1), 1.0, rtol=1e-5)


def test_top_p_always_keeps_argmax():
    logits = jnp.asarray([[10.0, 0.0, -1.0, -2.0]])
    sp = SamplingParams(temperature=1.0, top_p=0.01)
    p = np.asarray(SMP.target_probs(logits, sp))[0]
    assert p.argmax() == 0 and p[0] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Rejection sampler
# ---------------------------------------------------------------------------


def test_speculative_verify_greedy_exact_match(rng):
    B, K, V = 4, 3, 12
    logits = jnp.asarray(rng.normal(size=(B, K + 1, V)), jnp.float32)
    pred = np.asarray(jnp.argmax(logits, -1))
    drafts = pred[:, :K].copy()
    drafts[1, 1] = (drafts[1, 1] + 1) % V        # mismatch at j=1
    drafts[2, 0] = (drafts[2, 0] + 1) % V        # mismatch at j=0
    a, nxt = SMP.speculative_verify(logits, jnp.asarray(drafts),
                                    jax.random.PRNGKey(0), SamplingParams())
    np.testing.assert_array_equal(np.asarray(a), [K, 1, 0, K])
    np.testing.assert_array_equal(np.asarray(nxt),
                                  pred[np.arange(B), np.asarray(a)])


def test_speculative_verify_distribution_preserving(rng):
    """P(emitted token at a drafted position) must equal the target
    distribution regardless of what was drafted: accept d w.p. p(d),
    else resample from p with d removed — the mixture is exactly p."""
    V, B = 6, 8000
    row = np.log(np.asarray([0.35, 0.25, 0.2, 0.1, 0.07, 0.03], np.float32))
    sp = SamplingParams(temperature=1.0)
    logits = jnp.broadcast_to(jnp.asarray(row), (B, 2, V))
    for d in (0, 3, 5):                      # well-, mid- and badly-drafted
        drafts = jnp.full((B, 1), d, jnp.int32)
        a, nxt = SMP.speculative_verify(logits, drafts,
                                        jax.random.PRNGKey(d), sp)
        a, nxt = np.asarray(a), np.asarray(nxt)
        emitted = np.where(a == 1, d, nxt)   # the token at position 0
        freq = np.bincount(emitted, minlength=V) / B
        np.testing.assert_allclose(freq, np.exp(row), atol=0.02)
        # acceptance rate itself is p(d)
        assert abs(a.mean() - np.exp(row[d])) < 0.02


def test_speculative_verify_temperature_zero_equals_greedy(rng):
    B, K, V = 3, 2, 9
    logits = jnp.asarray(rng.normal(size=(B, K + 1, V)), jnp.float32)
    drafts = jnp.asarray(rng.integers(0, V, size=(B, K)), jnp.int32)
    a0, n0 = SMP.speculative_verify(logits, drafts, jax.random.PRNGKey(1),
                                    SamplingParams(temperature=0.0))
    pred = np.asarray(jnp.argmax(logits, -1))
    ok = pred[:, :K] == np.asarray(drafts)
    want_a = np.cumprod(ok, 1).sum(1)
    np.testing.assert_array_equal(np.asarray(a0), want_a)
    np.testing.assert_array_equal(np.asarray(n0),
                                  pred[np.arange(B), want_a])


# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------


def test_ngram_drafter_prompt_lookup():
    d = SPEC.NgramDrafter(k=3, max_ngram=3)
    # context repeats "7 8 9 10" — trailing [8, 9] matched earlier,
    # propose what followed: [10, 5, 6]
    ctx = [5, 6, 7, 8, 9, 10, 5, 6, 7, 8, 9]
    assert d.propose(ctx) == [10, 5, 6]
    # no match anywhere: repeat the last token
    assert d.propose([1, 2, 3]) == [3, 3, 3]
    # match with a short continuation pads by repeating its last token
    assert d.propose([4, 9, 4, 9])[0] == 4


def test_ngram_drafter_slots_mask_inactive():
    d = SPEC.NgramDrafter(k=2)
    out = d.propose_slots([None, [1, 2, 1, 2], None])
    assert out.shape == (3, 2)
    assert (out[0] == 0).all() and (out[2] == 0).all()
    assert out[1].tolist() == [1, 2]


def test_draft_model_drafter_matches_own_greedy(rng):
    """Self-drafting proposes exactly the model's own greedy
    continuation (which is why self-draft verify accepts everything)."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    ctx = [2] + list(map(int, rng.integers(4, 400, size=7)))
    gen = eng.generate_batch(np.asarray([ctx], np.int32),
                             np.asarray([len(ctx)], np.int32), 3,
                             stop_at_eos=False)
    d = SPEC.DraftModelDrafter(cfg, params, k=3, policy=FP32)
    prop = d.propose(ctx)
    assert prop == [int(t) for t in gen[0]]
    # batched slot drafting agrees with per-context drafting
    ctx2 = [2] + list(map(int, rng.integers(4, 400, size=12)))
    both = d.propose_slots([ctx, None, ctx2])
    assert both[0].tolist() == prop
    assert (both[1] == 0).all()
    assert both[2].tolist() == d.propose(ctx2)


def test_get_drafter_resolution():
    spec = SPEC.SpecConfig(k=2, drafter="ngram", max_ngram=4)
    d = SPEC.get_drafter(spec)
    assert isinstance(d, SPEC.NgramDrafter) and d.max_ngram == 4
    with pytest.raises(ValueError):
        SPEC.get_drafter(SPEC.SpecConfig(drafter="draft_model"))
    with pytest.raises(ValueError):
        SPEC.get_drafter(SPEC.SpecConfig(drafter="wat"))


# ---------------------------------------------------------------------------
# Multi-token paged write + truncate (rollback)
# ---------------------------------------------------------------------------


def _pool(P, page, H, D, int8=False):
    if int8:
        return {"pk": jnp.zeros((P, page, H, D), jnp.int8),
                "pv": jnp.zeros((P, page, H, D), jnp.int8),
                "pk_scale": jnp.zeros((P, page, H), jnp.float32),
                "pv_scale": jnp.zeros((P, page, H), jnp.float32),
                "ppos": jnp.full((P, page), -1, jnp.int32)}
    return {"pk": jnp.zeros((P, page, H, D)),
            "pv": jnp.zeros((P, page, H, D)),
            "ppos": jnp.full((P, page), -1, jnp.int32)}


@pytest.mark.parametrize("int8", [False, True])
def test_multi_write_truncate_across_page_boundary(rng, int8):
    """Write a K+1 window straddling a page boundary, roll back to an
    accepted prefix, and check the gather sees exactly the accepted
    tokens (int8: stale codes/scales unreachable, live ones within the
    quantization bound)."""
    P, page, H, D = 6, 8, 2, 16
    pool = _pool(P, page, H, D, int8)
    bt = jnp.asarray([[0, 3, -1, -1]], jnp.int32)
    ring = KV.paged_ring_len(None, page, 4)
    k = jnp.asarray(rng.normal(size=(1, 4, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 4, H, D)), jnp.float32)
    # window at positions 6..9 crosses the page-0/page-1 boundary
    pool = KV.paged_write_decode_multi(pool, {"k": k, "v": v},
                                       jnp.asarray([6], jnp.int32), bt,
                                       jnp.asarray([True]), ring_len=ring)
    _, _, kp = KV.paged_gather(pool, bt)
    assert set(np.asarray(kp[0])[np.asarray(kp[0]) >= 0]) == {6, 7, 8, 9}
    # accept 1 draft: keep positions < 8 (pending@6 + draft@7)
    pool = KV.paged_truncate(pool, bt, jnp.asarray([8], jnp.int32))
    kk, vv, kp = KV.paged_gather(pool, bt)
    live = np.asarray(kp[0])
    assert set(live[live >= 0]) == {6, 7}
    got_k = np.asarray(kk[0])[live >= 0]
    want_k = np.asarray(k[0, :2])
    if int8:
        bound = np.abs(want_k).max(-1, keepdims=True) / 254.0
        assert (np.abs(got_k - want_k) <= bound + 1e-7).all()
    else:
        np.testing.assert_allclose(got_k, want_k, rtol=1e-6)
    # the rewound entries' codes are unreachable: rewriting those
    # positions with new values fully defines what a later gather sees
    k2, v2 = k + 5.0, v - 5.0
    pool = KV.paged_write_decode_multi(pool, {"k": k2, "v": v2},
                                       jnp.asarray([8], jnp.int32), bt,
                                       jnp.asarray([True]), ring_len=ring)
    kk, _, kp = KV.paged_gather(pool, bt)
    live = np.asarray(kp[0])
    assert set(live[live >= 0]) == {6, 7, 8, 9, 10, 11}


def test_multi_write_respects_active_and_allocation(rng):
    P, page, H, D = 5, 8, 1, 8
    pool = _pool(P, page, H, D)
    bt = jnp.asarray([[0, -1, -1], [1, -1, -1]], jnp.int32)
    ring = KV.paged_ring_len(None, page, 3)
    k = jnp.asarray(rng.normal(size=(2, 3, H, D)), jnp.float32)
    # slot 0 inactive -> dump; slot 1 window runs past its single
    # allocated page -> overflow entries dump, no wrap
    pool = KV.paged_write_decode_multi(
        pool, {"k": k, "v": k}, jnp.asarray([2, 6], jnp.int32), bt,
        jnp.asarray([False, True]), ring_len=ring)
    assert int(pool["ppos"][0].max()) == -1          # inactive: untouched
    assert int(pool["ppos"][P - 1].max()) == -1      # dump stays empty
    live = np.asarray(pool["ppos"][1])
    assert set(live[live >= 0]) == {6, 7}            # 8 fell off page 0
    # beyond ring_len is dumped, never wrapped onto early pages
    pool2 = KV.paged_write_decode_multi(
        pool, {"k": k, "v": k}, jnp.asarray([22, 22], jnp.int32),
        jnp.asarray([[0, 2, 3], [1, 2, 3]], jnp.int32),
        None, ring_len=ring)
    for p in range(P - 1):
        live = np.asarray(pool2["ppos"][p])
        assert not ((live >= 0) & (live < 6)).any()


def test_truncate_scan_repeats_layout_and_shared_rows(rng):
    """The (R, P, page) scan-stacked layout truncates correctly, and a
    page mapped by two slots (shared prefix) survives both rows'
    write-backs."""
    P, page, R = 7, 4, 3
    ppos = np.full((R, P, page), -1, np.int32)
    ppos[:, 2] = [0, 1, 2, 3]                 # shared prefix page
    ppos[:, 0, :3] = [4, 5, 6]                # slot 0 tail
    ppos[:, 4, :2] = [4, 5]                   # slot 1 tail
    pool = {"pk": jnp.zeros((R, P, page, 1, 8)),
            "pv": jnp.zeros((R, P, page, 1, 8)),
            "ppos": jnp.asarray(ppos)}
    bt = jnp.asarray([[2, 0, -1], [2, 4, -1]], jnp.int32)
    out = KV.paged_truncate(pool, bt, jnp.asarray([6, 5], jnp.int32))
    got = np.asarray(out["ppos"])
    for r in range(R):
        assert got[r, 2].tolist() == [0, 1, 2, 3]         # shared intact
        assert got[r, 0].tolist() == [4, 5, -1, -1]       # 6 rewound
        assert got[r, 4].tolist() == [4, -1, -1, -1]      # 5 rewound
        assert got[r, P - 1].tolist() == [-1] * page      # dump intact


class _RandomDrafter(SPEC.Drafter):
    """Adversarial drafter: random tokens (mostly rejected) with
    occasional EOS proposals — exercises rollback, EOS-in-window and
    budget edges."""

    name = "random"

    def __init__(self, k, seed=0):
        super().__init__(k)
        self.rng = np.random.default_rng(seed)

    def propose(self, context):
        out = self.rng.integers(4, 400, size=self.k)
        if self.rng.random() < 0.15:
            out[self.rng.integers(0, self.k)] = EOS
        return [int(t) for t in out]


def _spec_invariant_trial(seed: int, k: int):
    """Serve a random trace with an adversarial drafter; the engine's
    own end-of-serve audit (allocator.check() + trie residency) plus
    greedy parity vs the non-speculative run make up the invariant."""
    rng = np.random.default_rng(seed)
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = [Request(uid=i,
                    tokens=[2] + list(map(int, rng.integers(4, 400,
                                                            size=ln))),
                    max_new_tokens=mn)
            for i, (ln, mn) in enumerate(
                zip(rng.integers(2, 18, size=5), rng.integers(1, 8, size=5)))]
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    base, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   prefix_cache=True)
    eng2 = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                           max_batch=2)
    import repro.core.engine as E
    orig = E.get_drafter
    E.get_drafter = lambda spec, *_a, **_k: _RandomDrafter(spec.k,
                                                           seed=seed)
    try:
        done, m = eng2.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                        spec=SPEC.SpecConfig(k=k),
                                        prefix_cache=True)
    finally:
        E.get_drafter = orig
    for a, b in zip(base, done):
        assert a.result == b.result, f"seed {seed} uid {a.uid}"
    assert m.drafted_tokens >= m.accepted_tokens >= 0


SEED_TRIALS = [(0, 2), (1, 3), (2, 4)]


@pytest.mark.parametrize("seed,k", SEED_TRIALS)
def test_spec_rollback_invariants_seeded(seed, k):
    _spec_invariant_trial(seed, k)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10_000), st.integers(1, 4))
    @settings(max_examples=8, deadline=None)
    def test_spec_rollback_invariants_hypothesis(seed, k):
        _spec_invariant_trial(seed, k)


# ---------------------------------------------------------------------------
# Multi-query verify kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------


def _random_paged_state(rng, B, P, page, npages, Hkv, D, Dv, K1):
    ppos = np.full((P, page), -1, np.int32)
    bt = np.full((B, npages), -1, np.int32)
    q_pos = np.zeros((B, K1), np.int32)
    perm = rng.permutation(P - 1)
    nxt = 0
    for b in range(B):
        ctx = int(rng.integers(K1, npages * page))
        q_pos[b] = ctx - K1 + np.arange(K1)
        used = -(-ctx // page)
        bt[b, :used] = perm[nxt:nxt + used]
        nxt += used
        for t in range(ctx):
            ppos[bt[b, t // page], t % page] = t
    return ppos, bt, q_pos


@pytest.mark.parametrize(
    "B,P,page,npages,Hq,Hkv,D,Dv,K1,window,cap",
    [
        (2, 9, 16, 4, 4, 4, 64, 64, 4, None, None),     # MHA
        (3, 13, 32, 3, 8, 2, 64, 64, 3, None, None),    # GQA 4:1
        (2, 9, 16, 4, 16, 4, 128, 128, 2, 24, None),    # GQA + window
        (2, 9, 16, 4, 4, 2, 64, 64, 5, None, 50.0),     # softcap
        (1, 7, 16, 4, 6, 2, 32, 32, 1, 20, 30.0),       # K1=1 degenerate
    ])
def test_paged_verify_kernel_vs_oracle(rng, B, P, page, npages, Hq, Hkv,
                                       D, Dv, K1, window, cap):
    ppos, bt, q_pos = _random_paged_state(rng, B, P, page, npages, Hkv, D,
                                          Dv, K1)
    kpool = jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32)
    vpool = jnp.asarray(rng.normal(size=(P, page, Hkv, Dv)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, K1, Hq, D)), jnp.float32)
    assert DA.paged_verify_shape_supported(q, kpool, jnp.asarray(bt))
    out = DA.paged_verify_attention(
        q, kpool, vpool, jnp.asarray(ppos), jnp.asarray(bt),
        jnp.asarray(q_pos), window=window, scale=D ** -0.5,
        attn_softcap=cap, interpret=True)
    ref = R.paged_verify_attention_ref(
        q, kpool, vpool, jnp.asarray(ppos), jnp.asarray(bt),
        jnp.asarray(q_pos), window=window, scale=D ** -0.5,
        attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "B,P,page,npages,Hq,Hkv,D,K1,window,cap",
    [
        (2, 9, 16, 4, 4, 4, 64, 4, None, None),
        (2, 9, 16, 3, 8, 2, 64, 3, 24, 50.0),
    ])
def test_paged_verify_q8_kernel_vs_oracle(rng, B, P, page, npages, Hq, Hkv,
                                          D, K1, window, cap):
    ppos, bt, q_pos = _random_paged_state(rng, B, P, page, npages, Hkv, D,
                                          D, K1)
    kq, ks = KV.quantize_kv(
        jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32))
    vq, vs = KV.quantize_kv(
        jnp.asarray(rng.normal(size=(P, page, Hkv, D)), jnp.float32))
    q = jnp.asarray(rng.normal(size=(B, K1, Hq, D)), jnp.float32)
    out = DA.paged_verify_attention_q8(
        q, kq, ks, vq, vs, jnp.asarray(ppos), jnp.asarray(bt),
        jnp.asarray(q_pos), window=window, scale=D ** -0.5,
        attn_softcap=cap, interpret=True)
    ref = R.paged_verify_attention_ref(
        q, kq, vq, jnp.asarray(ppos), jnp.asarray(bt), jnp.asarray(q_pos),
        window=window, scale=D ** -0.5, attn_softcap=cap,
        k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# forward_verify vs sequential decode
# ---------------------------------------------------------------------------


def _paged_two_slots(cfg, params, rng, max_len=64, page_size=8):
    slots = 2
    pages_per_slot = max_len // page_size
    num_pages = slots * pages_per_slot
    cache = T.init_paged_cache(cfg, num_pages=num_pages,
                               page_size=page_size, max_slots=slots,
                               max_len=max_len, dtype=jnp.float32)
    bt = np.full((slots, pages_per_slot), -1, np.int32)
    bt[0] = np.arange(pages_per_slot)
    bt[1] = np.arange(pages_per_slot, 2 * pages_per_slot)
    lens = np.asarray([6, 9], np.int32)
    S = int(lens.max())
    prompt = np.zeros((slots, S), np.int32)
    for b in range(slots):
        prompt[b, :lens[b]] = [2] + list(rng.integers(4, 400,
                                                      size=lens[b] - 1))
    view = KV.slot_view(cache, slots)
    paged = {"block_tables": jnp.asarray(bt),
             "active": jnp.ones((slots,), bool)}
    _, view = T.forward_prefill(params, cfg, jnp.asarray(prompt),
                                jnp.asarray(lens), view, policy=FP32,
                                max_len=max_len, last_only=True,
                                paged=paged)
    cache = KV.slot_merge(cache, view,
                          jnp.asarray(np.arange(slots), np.int32))
    return cache, paged, lens


def test_forward_verify_matches_sequential_decode(rng):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache, paged, lens = _paged_two_slots(cfg, params, rng)
    K1 = 4
    toks = np.asarray(rng.integers(4, 400, size=(2, K1)), np.int32)
    seq = []
    c1 = cache
    for j in range(K1):
        lg, c1 = T.forward_decode(params, cfg, jnp.asarray(toks[:, j:j + 1]),
                                  c1, jnp.asarray(lens + j), policy=FP32,
                                  max_len=64, paged=paged)
        seq.append(np.asarray(lg[:, 0]))
    seq = np.stack(seq, axis=1)
    vl, c2 = T.forward_verify(params, cfg, jnp.asarray(toks), cache,
                              jnp.asarray(lens), policy=FP32, max_len=64,
                              paged=paged)
    np.testing.assert_array_equal(np.asarray(vl), seq)
    # the verify write leaves the same cache positions as the sequence
    for sc1, sc2 in zip(c1["layers"], c2["layers"]):
        for a, b in zip(sc1, sc2):
            np.testing.assert_array_equal(np.asarray(a["ppos"]),
                                          np.asarray(b["ppos"]))


def test_forward_verify_kernel_interpret_matches_fallback():
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache, paged, lens = _paged_two_slots(cfg, params,
                                          np.random.default_rng(5))
    toks = np.asarray(np.random.default_rng(6).integers(4, 400,
                                                        size=(2, 3)),
                      np.int32)
    base, _ = T.forward_verify(params, cfg, jnp.asarray(toks), cache,
                               jnp.asarray(lens), policy=FP32, max_len=64,
                               paged=paged)
    with KOPS.kernel_mode_ctx("interpret"):
        cache3, paged3, lens3 = _paged_two_slots(cfg, params,
                                                 np.random.default_rng(5))
        kout, _ = T.forward_verify(params, cfg, jnp.asarray(toks), cache3,
                                   jnp.asarray(lens3), policy=FP32,
                                   max_len=64, paged=paged3)
    np.testing.assert_allclose(np.asarray(kout), np.asarray(base),
                               rtol=2e-4, atol=2e-4)


def test_forward_verify_rejects_dense_and_recurrent():
    cfg = get_reduced("xlstm-125m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, 1, 32, jnp.float32)
    with pytest.raises(NotImplementedError):
        T.forward_verify(params, cfg, jnp.zeros((1, 3), jnp.int32), cache,
                         jnp.asarray([4], jnp.int32), policy=FP32,
                         max_len=32)


# ---------------------------------------------------------------------------
# End-to-end speculative serving
# ---------------------------------------------------------------------------


def _requests(rng, lens_new):
    return [Request(uid=i,
                    tokens=[2] + list(map(int, rng.integers(4, 400,
                                                            size=ln))),
                    max_new_tokens=mn)
            for i, (ln, mn) in enumerate(lens_new)]


def _reference(cfg, params, reqs, policy=FP32):
    eng = InferenceEngine(cfg, params, policy=policy, max_len=64,
                          max_batch=2)
    out = {}
    for r in reqs:
        g = eng.generate_batch(np.asarray([r.tokens], np.int32),
                               np.asarray([len(r.tokens)], np.int32),
                               r.max_new_tokens)
        row = g[0]
        out[r.uid] = [int(t) for t in row[row >= 0]]
    return out


@pytest.mark.parametrize("drafter,prefix", [
    ("ngram", False), ("ngram", True), ("draft_model", True)])
def test_spec_serving_greedy_parity(rng, drafter, prefix):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, [(5, 8), (11, 6), (3, 9), (20, 5)])
    ref = _reference(cfg, params, reqs)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    done, m = eng.serve_continuous(
        copy.deepcopy(reqs), page_size=8,
        spec=SPEC.SpecConfig(k=3, drafter=drafter), prefix_cache=prefix)
    for r in done:
        assert r.result == ref[r.uid], f"uid {r.uid}"
    assert m.spec_mode == drafter and m.spec_k == 3
    assert m.drafted_tokens > 0
    if drafter == "draft_model":          # self-draft: greedy is accepted
        assert m.acceptance_rate > 0.5
        assert m.tokens_per_forward > 1.5


def test_spec_serving_int8_parity(rng):
    """Speculative + int8 pools + prefix sharing: bit-identical to the
    non-speculative int8 run (scale pools rewound with the codes)."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prefix = list(map(int, np.random.default_rng(7).integers(4, 400,
                                                             size=17)))
    reqs = []
    for i, (ln, mn) in enumerate([(5, 6), (3, 5), (7, 6), (4, 5)]):
        body = list(map(int, rng.integers(4, 400, size=ln)))
        reqs.append(Request(uid=i, tokens=[2] + prefix + body,
                            max_new_tokens=mn))
    eng = InferenceEngine(cfg, params, policy=INT8, max_len=64, max_batch=2)
    base, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   prefix_cache=True)
    eng2 = InferenceEngine(cfg, params, policy=INT8, max_len=64,
                           max_batch=2)
    done, m = eng2.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                    spec=SPEC.SpecConfig(k=3,
                                                         drafter="ngram"),
                                    prefix_cache=True)
    for a, b in zip(base, done):
        assert a.result == b.result, f"uid {a.uid}"
    assert m.kv_dtype == "int8" and m.prefix_matched_tokens > 0


def test_spec_serving_kernel_interpret(rng):
    """The multi-query verify kernel in interpret mode serves the same
    greedy streams as the gather fallback."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, [(5, 5), (9, 5), (14, 4)])
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=3)
    base, _ = eng.serve_continuous(
        copy.deepcopy(reqs), page_size=8,
        spec=SPEC.SpecConfig(k=2, drafter="draft_model"),
        prefix_cache=False)
    eng2 = InferenceEngine(cfg, params, policy=FP32, max_len=64,
                           max_batch=3)
    with KOPS.kernel_mode_ctx("interpret"):
        done, _ = eng2.serve_continuous(
            copy.deepcopy(reqs), page_size=8,
            spec=SPEC.SpecConfig(k=2, drafter="draft_model"),
            prefix_cache=False)
    for a, b in zip(base, done):
        assert a.result == b.result


def test_spec_serving_budget_edges(rng):
    """max_new of 0/1/2 with speculation: budgets never overshoot."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, [(5, 0), (5, 1), (5, 2), (6, 7)])
    ref = _reference(cfg, params, reqs)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    done, _ = eng.serve_continuous(
        copy.deepcopy(reqs), page_size=8,
        spec=SPEC.SpecConfig(k=3, drafter="draft_model"))
    for r in done:
        assert r.result == ref[r.uid], f"uid {r.uid}"
        assert len(r.result) <= r.max_new_tokens


def test_spec_serving_eos_in_window(rng, monkeypatch):
    """An accepted drafted EOS retires the request without emitting EOS
    or anything after it."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, [(5, 8), (9, 8)])
    ref = _reference(cfg, params, reqs)
    import repro.core.engine as E

    class EosDrafter(SPEC.Drafter):
        name = "eos"

        def propose(self, context):
            # propose the model's own continuation with EOS spliced in —
            # the verifier must cut at EOS iff the model agrees
            d = SPEC.DraftModelDrafter(cfg, params, self.k)
            out = d.propose(context)
            out[-1] = EOS
            return out

    monkeypatch.setattr(E, "get_drafter",
                        lambda spec, *a, **k: EosDrafter(spec.k))
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    done, _ = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                   spec=SPEC.SpecConfig(k=3))
    for r in done:
        assert r.result == ref[r.uid]
        assert EOS not in r.result


def test_spec_disabled_for_unsupported_families(rng):
    """Windowed attention warns and serves non-speculatively (ring pages
    cannot be rolled back)."""
    cfg = get_reduced("gemma2-2b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, [(5, 4), (9, 4)])
    ref = _reference(cfg, params, reqs)
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    with pytest.warns(UserWarning, match="speculative decoding"):
        done, m = eng.serve_continuous(copy.deepcopy(reqs), page_size=8,
                                       spec=SPEC.SpecConfig(k=3))
    assert m.spec_mode == "off" and m.drafted_tokens == 0
    for r in done:
        assert r.result == ref[r.uid]


def test_spec_sampled_serving_valid(rng):
    """Sampled speculative serving emits valid tokens within budget
    (distribution preservation is tested at the sampler level)."""
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, [(5, 6), (9, 6), (3, 6)])
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2,
                          seed=11)
    done, m = eng.serve_continuous(
        reqs, SamplingParams(temperature=1.0, top_k=20, top_p=0.9),
        page_size=8, spec=SPEC.SpecConfig(k=2))
    for r in done:
        assert r.result is not None and len(r.result) <= 6
        assert all(0 <= t < cfg.vocab_size and t != EOS for t in r.result)
    assert m.drafted_tokens > 0


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------


def test_serve_metrics_spec_zero_guards():
    m = ServeMetrics()
    assert m.acceptance_rate == 0.0
    assert m.tokens_per_forward == 0.0
    assert m.prefill_pad_frac == 0.0
    assert m.decode_idle_frac == 0.0
    assert m.prefix_hit_rate == 0.0
    assert m.percentile_latency(50) == 0.0
    assert m.spec_mode == "off" and m.spec_k == 0


def test_spec_metrics_accounting(rng):
    cfg = get_reduced("qwen3-4b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(rng, [(6, 6), (10, 6)])
    eng = InferenceEngine(cfg, params, policy=FP32, max_len=64, max_batch=2)
    _, m = eng.serve_continuous(
        copy.deepcopy(reqs), page_size=8,
        spec=SPEC.SpecConfig(k=4, drafter="draft_model"))
    assert m.spec_k == 4
    assert 0 < m.accepted_tokens <= m.drafted_tokens
    assert m.decode_tokens + m.admitted == m.generated_tokens
    # self-draft accepts greedily: strictly more than one token per
    # live slot-forward
    assert m.tokens_per_forward > 1.0
